//! GRU sequence layer and one-hot encoding — the Char-RNN stack (paper
//! §4.2.3, Fig 9).
//!
//! The paper unrolls a recurrent layer into `unroll_len` directed
//! sub-layers (Fig 5b). Here a `GruLayer` processes the whole sequence:
//! `compute_feature` runs the unrolled forward loop, `compute_gradient`
//! runs back-propagation-through-time, so the BP `TrainOneBatch` algorithm
//! drives BPTT exactly as the paper describes ("for feed-forward and
//! recurrent models, the BP algorithm is provided"). Stacked GRU layers are
//! separate `GruLayer` instances, which is the unit of placement used by the
//! partitioning example (different stacks → different workers).
//!
//! Sequence blobs are `[batch, steps*dim]` row-major with step-major inner
//! layout (step t occupies columns `[t*dim, (t+1)*dim)`).

use super::layer::{Layer, Phase};
use crate::tensor::blob::Param;
use crate::tensor::{ops, Blob};
use crate::utils::rng::Rng;
use std::any::Any;

/// Gated recurrent unit over full sequences.
///
/// Gates (per step): `r = σ(x Wr + h Ur + br)`, `z = σ(x Wz + h Uz + bz)`,
/// candidate `c = tanh(x Wc + (r⊙h) Uc + bc)`, `h' = z⊙h + (1-z)⊙c`.
pub struct GruLayer {
    name: String,
    hidden: usize,
    steps: usize,
    init_std: f32,
    in_dim: usize,
    // Parameters: the three input projections stacked [in_dim, 3*hidden]
    // (r|z|c), the three recurrent projections [hidden, 3*hidden], bias
    // [3*hidden]. Stacking keeps the param-server shard count small.
    w: Param,
    u: Param,
    b: Param,
    // Per-step caches from the last forward pass (batch-major blobs).
    cache: Vec<StepCache>,
    h0: Blob,
}

struct StepCache {
    x: Blob,
    h_prev: Blob,
    r: Blob,
    z: Blob,
    c: Blob,
    h: Blob,
}

impl GruLayer {
    pub fn new(name: &str, hidden: usize, steps: usize, init_std: f32) -> GruLayer {
        GruLayer {
            name: name.to_string(),
            hidden,
            steps,
            init_std,
            in_dim: 0,
            w: Param::new(&format!("{name}/w"), Blob::zeros(&[0])),
            u: Param::new(&format!("{name}/u"), Blob::zeros(&[0])),
            b: Param::new(&format!("{name}/b"), Blob::zeros(&[0])),
            cache: Vec::new(),
            h0: Blob::zeros(&[0]),
        }
    }

    pub fn steps(&self) -> usize {
        self.steps
    }

    fn gates(&self, x: &Blob, h: &Blob) -> (Blob, Blob, Blob) {
        // pre = x W + h U + b (candidate's recurrent term handled separately)
        let hd = self.hidden;
        let mut pre = ops::matmul(x, &self.w.data);
        ops::add_row_vec(&mut pre, &self.b.data);
        let pre_rec = ops::matmul(h, &self.u.data);
        let batch = x.rows();
        let mut r = Blob::zeros(&[batch, hd]);
        let mut z = Blob::zeros(&[batch, hd]);
        let mut cpre = Blob::zeros(&[batch, hd]);
        for bi in 0..batch {
            for j in 0..hd {
                let base = bi * 3 * hd;
                r.data_mut()[bi * hd + j] = pre.data()[base + j] + pre_rec.data()[base + j];
                z.data_mut()[bi * hd + j] =
                    pre.data()[base + hd + j] + pre_rec.data()[base + hd + j];
                // candidate input projection only; recurrent part needs r⊙h
                cpre.data_mut()[bi * hd + j] = pre.data()[base + 2 * hd + j];
            }
        }
        (ops::sigmoid(&r), ops::sigmoid(&z), cpre)
    }
}

impl Layer for GruLayer {
    fn name(&self) -> &str {
        &self.name
    }

    fn type_name(&self) -> &'static str {
        "Gru"
    }

    fn setup(&mut self, src_shapes: &[&[usize]], rng: &mut Rng) -> Vec<usize> {
        let s = src_shapes[0];
        assert_eq!(s.len(), 2, "{}: Gru wants [batch, steps*dim]", self.name);
        assert_eq!(s[1] % self.steps, 0, "{}: cols not divisible by steps", self.name);
        self.in_dim = s[1] / self.steps;
        let hd = self.hidden;
        self.w = Param::new(
            &format!("{}/w", self.name),
            Blob::gaussian(&[self.in_dim, 3 * hd], self.init_std, rng),
        );
        self.u = Param::new(
            &format!("{}/u", self.name),
            Blob::gaussian(&[hd, 3 * hd], self.init_std, rng),
        );
        self.b = Param::new(&format!("{}/b", self.name), Blob::zeros(&[3 * hd])).with_wd_mult(0.0);
        vec![s[0], self.steps * hd]
    }

    fn compute_feature(&mut self, _phase: Phase, srcs: &[&Blob]) -> Blob {
        let xseq = srcs[0];
        let batch = xseq.rows();
        let hd = self.hidden;
        let mut h = Blob::zeros(&[batch, hd]);
        self.h0 = h.clone();
        self.cache.clear();
        let mut out = Blob::zeros(&[batch, self.steps * hd]);
        for t in 0..self.steps {
            let x = step_slice(xseq, t, self.in_dim, self.steps);
            let (r, z, cpre_in) = self.gates(&x, &h);
            // candidate: tanh(cpre_in + (r ⊙ h) Uc)
            let rh = ops::zip(&r, &h, |a, b| a * b);
            let rec = ops::matmul(&rh, &slice_u_c(&self.u.data, hd));
            let cpre = ops::zip(&cpre_in, &rec, |a, b| a + b);
            let c = ops::tanh(&cpre);
            let h_new = {
                let zh = ops::zip(&z, &h, |a, b| a * b);
                let zc = ops::zip(&z, &c, |zv, cv| (1.0 - zv) * cv);
                ops::zip(&zh, &zc, |a, b| a + b)
            };
            write_step(&mut out, &h_new, t, hd, self.steps);
            self.cache.push(StepCache {
                x,
                h_prev: h.clone(),
                r,
                z,
                c,
                h: h_new.clone(),
            });
            h = h_new;
        }
        out
    }

    fn compute_gradient(
        &mut self,
        srcs: &[&Blob],
        _own: &Blob,
        grad_out: Option<&Blob>,
    ) -> Vec<Option<Blob>> {
        let dy_seq = grad_out.expect("Gru needs grad");
        let xseq = srcs[0];
        let batch = xseq.rows();
        let hd = self.hidden;
        let mut dx_seq = Blob::zeros(xseq.shape());
        let mut dh_next = Blob::zeros(&[batch, hd]);

        // dW/dU accumulate over steps; build locally then add to params.
        let mut dw = Blob::zeros(self.w.data.shape());
        let mut du = Blob::zeros(self.u.data.shape());
        let mut db = Blob::zeros(self.b.data.shape());

        for t in (0..self.steps).rev() {
            let sc = &self.cache[t];
            // Total gradient into h_t: from output at step t + from step t+1.
            let mut dh = step_slice(dy_seq, t, hd, self.steps);
            dh.add_assign(&dh_next);

            // h = z⊙h_prev + (1-z)⊙c
            let dz = ops::zip(
                &dh,
                &ops::zip(&sc.h_prev, &sc.c, |hp, cv| hp - cv),
                |d, diff| d * diff,
            );
            let dc = ops::zip(&dh, &sc.z, |d, zv| d * (1.0 - zv));
            let mut dh_prev = ops::zip(&dh, &sc.z, |d, zv| d * zv);

            // c = tanh(cpre); dcpre = dc * (1 - c^2)
            let dcpre = ops::zip(&dc, &sc.c, |d, cv| d * (1.0 - cv * cv));
            // cpre = x Wc + (r⊙h_prev) Uc + bc
            let rh = ops::zip(&sc.r, &sc.h_prev, |a, b| a * b);
            let uc = slice_u_c(&self.u.data, hd);
            let drh = ops::matmul_nt(&dcpre, &uc);
            // dUc += rh^T dcpre
            add_u_c(&mut du, &ops::matmul_tn(&rh, &dcpre), hd);
            let dr = ops::zip(&drh, &sc.h_prev, |d, hp| d * hp);
            dh_prev.add_assign(&ops::zip(&drh, &sc.r, |d, rv| d * rv));

            // gate pre-activations
            let drpre = ops::zip(&dr, &sc.r, |d, rv| d * rv * (1.0 - rv));
            let dzpre = ops::zip(&dz, &sc.z, |d, zv| d * zv * (1.0 - zv));

            // Assemble the stacked [batch, 3h] pre-activation gradient
            // (r|z|c): W and U(r,z) see the same layout; Uc was handled above.
            let mut dpre = Blob::zeros(&[batch, 3 * hd]);
            for bi in 0..batch {
                for j in 0..hd {
                    dpre.data_mut()[bi * 3 * hd + j] = drpre.data()[bi * hd + j];
                    dpre.data_mut()[bi * 3 * hd + hd + j] = dzpre.data()[bi * hd + j];
                    dpre.data_mut()[bi * 3 * hd + 2 * hd + j] = dcpre.data()[bi * hd + j];
                }
            }
            // dW += x^T dpre ; db += colsum(dpre)
            dw.add_assign(&ops::matmul_tn(&sc.x, &dpre));
            db.add_assign(&ops::sum_rows(&dpre));
            // dx = dpre W^T
            let dx = ops::matmul_nt(&dpre, &self.w.data);
            write_step(&mut dx_seq, &dx, t, self.in_dim, self.steps);

            // dU(r,z) from recurrent terms: pre_rec = h_prev U.
            // Only r,z columns: zero the c block of dpre first.
            let mut dpre_rz = dpre.clone();
            for bi in 0..batch {
                for j in 0..hd {
                    dpre_rz.data_mut()[bi * 3 * hd + 2 * hd + j] = 0.0;
                }
            }
            du.add_assign(&ops::matmul_tn(&sc.h_prev, &dpre_rz));
            dh_prev.add_assign(&{
                let full = ops::matmul_nt(&dpre_rz, &self.u.data);
                full
            });

            dh_next = dh_prev;
        }
        self.w.grad.add_assign(&dw);
        self.u.grad.add_assign(&du);
        self.b.grad.add_assign(&db);
        vec![Some(dx_seq)]
    }

    fn params(&self) -> Vec<&Param> {
        vec![&self.w, &self.u, &self.b]
    }

    fn params_mut(&mut self) -> Vec<&mut Param> {
        vec![&mut self.w, &mut self.u, &mut self.b]
    }

    fn as_any(&mut self) -> &mut dyn Any {
        self
    }
}

/// Extract step `t` of a `[batch, steps*dim]` sequence blob → `[batch, dim]`.
fn step_slice(seq: &Blob, t: usize, dim: usize, steps: usize) -> Blob {
    let batch = seq.rows();
    let mut out = Blob::zeros(&[batch, dim]);
    for b in 0..batch {
        let src = &seq.data()[b * steps * dim + t * dim..][..dim];
        out.data_mut()[b * dim..(b + 1) * dim].copy_from_slice(src);
    }
    out
}

/// Write step `t` of a sequence blob (accumulating assignment).
fn write_step(seq: &mut Blob, step: &Blob, t: usize, dim: usize, steps: usize) {
    let batch = step.rows();
    for b in 0..batch {
        let dst = &mut seq.data_mut()[b * steps * dim + t * dim..][..dim];
        for (d, s) in dst.iter_mut().zip(&step.data()[b * dim..(b + 1) * dim]) {
            *d += s;
        }
    }
}

/// View of the candidate block Uc = U[:, 2h..3h] as an owned [h, h] blob.
fn slice_u_c(u: &Blob, hd: usize) -> Blob {
    u.slice_cols(2 * hd, hd)
}

/// Accumulate dUc into the candidate block of dU.
fn add_u_c(du: &mut Blob, duc: &Blob, hd: usize) {
    let cols = 3 * hd;
    for r in 0..hd {
        for c in 0..hd {
            du.data_mut()[r * cols + 2 * hd + c] += duc.data()[r * hd + c];
        }
    }
}

/// One-hot layer: char ids `[batch, steps]` → `[batch, steps*vocab]`.
pub struct OneHotLayer {
    name: String,
    vocab: usize,
    steps: usize,
}

impl OneHotLayer {
    pub fn new(name: &str, vocab: usize) -> OneHotLayer {
        OneHotLayer { name: name.to_string(), vocab, steps: 0 }
    }
}

impl Layer for OneHotLayer {
    fn name(&self) -> &str {
        &self.name
    }

    fn type_name(&self) -> &'static str {
        "OneHot"
    }

    fn setup(&mut self, src_shapes: &[&[usize]], _rng: &mut Rng) -> Vec<usize> {
        let s = src_shapes[0];
        assert_eq!(s.len(), 2, "{}: OneHot wants [batch, steps]", self.name);
        self.steps = s[1];
        vec![s[0], self.steps * self.vocab]
    }

    fn compute_feature(&mut self, _phase: Phase, srcs: &[&Blob]) -> Blob {
        let ids = srcs[0];
        let batch = ids.rows();
        let mut out = Blob::zeros(&[batch, self.steps * self.vocab]);
        for b in 0..batch {
            for t in 0..self.steps {
                let id = ids.data()[b * self.steps + t] as usize;
                assert!(id < self.vocab, "{}: char id {id} >= vocab {}", self.name, self.vocab);
                out.data_mut()[b * self.steps * self.vocab + t * self.vocab + id] = 1.0;
            }
        }
        out
    }

    fn compute_gradient(
        &mut self,
        _srcs: &[&Blob],
        _own: &Blob,
        _grad: Option<&Blob>,
    ) -> Vec<Option<Blob>> {
        vec![None]
    }

    fn as_any(&mut self) -> &mut dyn Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn onehot_encodes() {
        let mut l = OneHotLayer::new("oh", 4);
        let out_shape = l.setup(&[&[2, 3]], &mut Rng::new(1));
        assert_eq!(out_shape, vec![2, 12]);
        let ids = Blob::from_vec(&[2, 3], vec![0., 1., 2., 3., 0., 1.]);
        let y = l.compute_feature(Phase::Train, &[&ids]);
        assert_eq!(y.sum(), 6.0);
        assert_eq!(y.data()[0], 1.0); // b0 t0 id0
        assert_eq!(y.data()[4 + 1], 1.0); // b0 t1 id1
        assert_eq!(y.data()[12 + 3], 1.0); // b1 t0 id3
    }

    #[test]
    fn gru_shapes() {
        let mut l = GruLayer::new("gru", 8, 5, 0.1);
        let out = l.setup(&[&[3, 5 * 4]], &mut Rng::new(2));
        assert_eq!(out, vec![3, 40]);
        assert_eq!(l.params().len(), 3);
        assert_eq!(l.w.data.shape(), &[4, 24]);
        assert_eq!(l.u.data.shape(), &[8, 24]);
    }

    #[test]
    fn gru_forward_bounded() {
        let mut l = GruLayer::new("gru", 6, 4, 0.5);
        l.setup(&[&[2, 4 * 3]], &mut Rng::new(3));
        let mut r = Rng::new(5);
        let x = Blob::from_vec(&[2, 12], r.uniform_vec(24, -1.0, 1.0));
        let y = l.compute_feature(Phase::Train, &[&x]);
        // GRU hidden state is a convex combination of tanh outputs → (-1, 1)
        assert!(y.data().iter().all(|&v| v.abs() < 1.0));
    }

    /// Full BPTT gradient check: dL/dx and dL/dW numerically.
    #[test]
    fn gru_bptt_gradcheck() {
        let steps = 3;
        let in_dim = 2;
        let hd = 4;
        let batch = 2;
        let mut l = GruLayer::new("gru", hd, steps, 0.4);
        l.setup(&[&[batch, steps * in_dim]], &mut Rng::new(7));
        let mut r = Rng::new(11);
        let x = Blob::from_vec(&[batch, steps * in_dim], r.uniform_vec(batch * steps * in_dim, -1.0, 1.0));

        let y = l.compute_feature(Phase::Train, &[&x]);
        let dy = Blob::full(y.shape(), 1.0);
        let gs = l.compute_gradient(&[&x], &y, Some(&dy));
        let dx = gs[0].clone().unwrap();
        let dw = l.w.grad.clone();
        let du = l.u.grad.clone();
        let db = l.b.grad.clone();

        let eps = 1e-2;
        let f_x = |l: &mut GruLayer, x: &Blob| l.compute_feature(Phase::Train, &[x]).sum();
        for i in 0..x.len() {
            let mut p = x.clone();
            p.data_mut()[i] += eps;
            let mut m = x.clone();
            m.data_mut()[i] -= eps;
            let num = (f_x(&mut l, &p) - f_x(&mut l, &m)) / (2.0 * eps);
            assert!(
                (num - dx.data()[i]).abs() < 3e-2,
                "dx[{i}] numeric {num} vs {}",
                dx.data()[i]
            );
        }
        // dW
        for i in (0..l.w.data.len()).step_by((l.w.data.len() / 10).max(1)) {
            let orig = l.w.data.data()[i];
            l.w.data.data_mut()[i] = orig + eps;
            let fp = f_x(&mut l, &x);
            l.w.data.data_mut()[i] = orig - eps;
            let fm = f_x(&mut l, &x);
            l.w.data.data_mut()[i] = orig;
            let num = (fp - fm) / (2.0 * eps);
            assert!((num - dw.data()[i]).abs() < 3e-2, "dW[{i}] {num} vs {}", dw.data()[i]);
        }
        // dU
        for i in (0..l.u.data.len()).step_by((l.u.data.len() / 10).max(1)) {
            let orig = l.u.data.data()[i];
            l.u.data.data_mut()[i] = orig + eps;
            let fp = f_x(&mut l, &x);
            l.u.data.data_mut()[i] = orig - eps;
            let fm = f_x(&mut l, &x);
            l.u.data.data_mut()[i] = orig;
            let num = (fp - fm) / (2.0 * eps);
            assert!((num - du.data()[i]).abs() < 3e-2, "dU[{i}] {num} vs {}", du.data()[i]);
        }
        // db
        for i in 0..db.len() {
            let orig = l.b.data.data()[i];
            l.b.data.data_mut()[i] = orig + eps;
            let fp = f_x(&mut l, &x);
            l.b.data.data_mut()[i] = orig - eps;
            let fm = f_x(&mut l, &x);
            l.b.data.data_mut()[i] = orig;
            let num = (fp - fm) / (2.0 * eps);
            assert!((num - db.data()[i]).abs() < 3e-2, "db[{i}] {num} vs {}", db.data()[i]);
        }
    }

    #[test]
    fn step_slice_write_roundtrip() {
        let mut r = Rng::new(1);
        let seq = Blob::from_vec(&[2, 6], r.uniform_vec(12, -1.0, 1.0));
        let mut rebuilt = Blob::zeros(&[2, 6]);
        for t in 0..3 {
            let s = step_slice(&seq, t, 2, 3);
            write_step(&mut rebuilt, &s, t, 2, 3);
        }
        assert_eq!(seq.data(), rebuilt.data());
    }
}
