//! Model checkpointing: serialize parameter tensors to a versioned binary
//! file and restore them into (possibly different) nets by name — the
//! mechanism the paper's deep auto-encoder uses to port RBM weights between
//! training stages ("the parameters trained from the first RBM are ported,
//! through checkpoint, into step 2", §4.2.2), and what a production job
//! needs for fault tolerance and warm starts.
//!
//! Format (little-endian):
//! ```text
//! magic "SNGA" | u32 version | u32 count |
//!   per param: u32 name_len | name bytes | u32 ndims | u64 dims... | f32 data...
//! ```

use crate::tensor::Blob;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"SNGA";
const VERSION: u32 = 1;
/// Header-field sanity bounds: a checkpoint claiming more params or more
/// elements per tensor than these is rejected before any payload work.
const MAX_PARAMS: usize = 1 << 20;
const MAX_ELEMS: usize = 1 << 30;
/// Payload read granularity (elements): preallocation per `reserve` call
/// is bounded by this, so memory tracks delivered bytes, not the header.
const READ_CHUNK_ELEMS: usize = 1 << 16;

/// A named set of tensors (what gets saved/restored).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Checkpoint {
    pub tensors: HashMap<String, Blob>,
}

impl Checkpoint {
    pub fn new() -> Checkpoint {
        Checkpoint::default()
    }

    /// Capture all parameters of a net (by `Param::name`).
    pub fn from_net(net: &crate::model::NeuralNet) -> Checkpoint {
        let mut c = Checkpoint::new();
        for p in net.params() {
            c.tensors.insert(p.name.clone(), p.data.clone());
        }
        c
    }

    /// Restore into a net: every param whose name matches is overwritten
    /// **in place** (`Blob::copy_from` into the existing buffer — zero Blob
    /// allocations when shapes agree). A shape mismatch aborts with an
    /// error naming the offending param; params matched before the mismatch
    /// keep their restored values (the net walk is in `params_mut` order).
    /// Returns the number restored.
    pub fn try_restore(&self, net: &mut crate::model::NeuralNet) -> Result<usize> {
        let mut n = 0;
        for p in net.params_mut() {
            if let Some(v) = self.tensors.get(&p.name) {
                if v.shape() != p.data.shape() {
                    return Err(anyhow!(
                        "checkpoint shape mismatch for '{}': checkpoint {:?} vs net {:?}",
                        p.name,
                        v.shape(),
                        p.data.shape()
                    ));
                }
                p.data.copy_from(v);
                n += 1;
            }
        }
        Ok(n)
    }

    /// Thin panicking wrapper over [`Checkpoint::try_restore`] for callers
    /// restoring a checkpoint they produced themselves (a mismatch is a
    /// bug, not an input error).
    pub fn restore(&self, net: &mut crate::model::NeuralNet) -> usize {
        // lint: panic-ok(documented panicking convenience wrapper over try_restore)
        self.try_restore(net).expect("checkpoint restore failed")
    }

    /// Serialize to a writer.
    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        // Sort for determinism.
        let mut names: Vec<&String> = self.tensors.keys().collect();
        names.sort();
        for name in names {
            let blob = &self.tensors[name];
            w.write_all(&(name.len() as u32).to_le_bytes())?;
            w.write_all(name.as_bytes())?;
            w.write_all(&(blob.shape().len() as u32).to_le_bytes())?;
            for &d in blob.shape() {
                w.write_all(&(d as u64).to_le_bytes())?;
            }
            for &v in blob.data() {
                w.write_all(&v.to_le_bytes())?;
            }
        }
        Ok(())
    }

    /// Deserialize from a reader.
    pub fn read_from<R: Read>(r: &mut R) -> Result<Checkpoint> {
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(anyhow!("not a singa checkpoint (bad magic)"));
        }
        let version = read_u32(r)?;
        if version != VERSION {
            return Err(anyhow!("unsupported checkpoint version {version}"));
        }
        let count = read_u32(r)? as usize;
        if count > MAX_PARAMS {
            return Err(anyhow!("implausible param count {count}"));
        }
        // Capacity follows delivered entries, not the untrusted header: a
        // lying `count` costs an error partway through, never a huge map.
        let mut tensors = HashMap::new();
        for _ in 0..count {
            let name_len = read_u32(r)? as usize;
            if name_len > 4096 {
                return Err(anyhow!("implausible name length {name_len}"));
            }
            let mut name = vec![0u8; name_len];
            r.read_exact(&mut name)?;
            let name = String::from_utf8(name).map_err(|_| anyhow!("non-utf8 param name"))?;
            let ndims = read_u32(r)? as usize;
            if ndims > 16 {
                return Err(anyhow!("implausible rank {ndims}"));
            }
            let mut shape = Vec::with_capacity(ndims);
            for _ in 0..ndims {
                let mut b = [0u8; 8];
                r.read_exact(&mut b)?;
                let d = u64::from_le_bytes(b);
                shape.push(
                    usize::try_from(d).map_err(|_| anyhow!("tensor dim {d} overflows usize"))?,
                );
            }
            // `iter().product()` wraps silently in release builds, letting
            // a crafted shape slip past the size guard — multiply checked.
            let mut n = 1usize;
            for &d in &shape {
                n = n
                    .checked_mul(d)
                    .ok_or_else(|| anyhow!("tensor element count overflows (shape {shape:?})"))?;
            }
            if n > MAX_ELEMS {
                return Err(anyhow!("implausible tensor size {n}"));
            }
            // Grow the payload buffer chunk by chunk so preallocation is
            // capped by what the reader has actually produced (plus one
            // chunk) — a huge claimed `n` over a truncated stream errors
            // out after at most 256 KiB, never a multi-GiB reserve.
            let mut data: Vec<f32> = Vec::new();
            let mut buf = [0u8; 4];
            let mut remaining = n;
            while remaining > 0 {
                let chunk = remaining.min(READ_CHUNK_ELEMS);
                data.reserve(chunk);
                for _ in 0..chunk {
                    r.read_exact(&mut buf)?;
                    data.push(f32::from_le_bytes(buf));
                }
                remaining -= chunk;
            }
            tensors.insert(name, Blob::from_vec(&shape, data));
        }
        Ok(Checkpoint { tensors })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        self.write_to(&mut f)
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        Checkpoint::read_from(&mut f)
    }

    /// Total bytes of tensor payload.
    pub fn byte_size(&self) -> usize {
        self.tensors.values().map(|b| b.byte_size()).sum()
    }
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layer::{Activation, LayerConf, LayerKind};
    use crate::model::NetBuilder;
    use crate::utils::quickcheck::{forall, prop_assert};
    use crate::utils::rng::Rng;

    fn small_net() -> crate::model::NeuralNet {
        NetBuilder::new()
            .add(LayerConf::new("data", LayerKind::Input { shape: vec![2, 4] }, &[]))
            .add(LayerConf::new(
                "fc",
                LayerKind::InnerProduct { out: 3, act: Activation::Tanh, init_std: 0.2 },
                &["data"],
            ))
            .build(&mut Rng::new(5))
    }

    #[test]
    fn roundtrip_in_memory() {
        let net = small_net();
        let c = Checkpoint::from_net(&net);
        let mut buf = Vec::new();
        c.write_to(&mut buf).unwrap();
        let c2 = Checkpoint::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(c, c2);
        assert_eq!(c.byte_size(), (4 * 3 + 3) * 4);
    }

    #[test]
    fn restore_into_fresh_net() {
        let net = small_net();
        let c = Checkpoint::from_net(&net);
        let mut fresh = NetBuilder::new()
            .add(LayerConf::new("data", LayerKind::Input { shape: vec![2, 4] }, &[]))
            .add(LayerConf::new(
                "fc",
                LayerKind::InnerProduct { out: 3, act: Activation::Tanh, init_std: 0.2 },
                &["data"],
            ))
            .build(&mut Rng::new(99)); // different init
        let restored = c.restore(&mut fresh);
        assert_eq!(restored, 2);
        for (a, b) in net.params().iter().zip(fresh.params()) {
            assert_eq!(a.data, b.data);
        }
    }

    #[test]
    fn partial_restore_by_name() {
        let net = small_net();
        let mut c = Checkpoint::from_net(&net);
        c.tensors.remove("fc/bias");
        let mut fresh = small_net();
        assert_eq!(c.restore(&mut fresh), 1);
    }

    #[test]
    fn file_roundtrip() {
        let net = small_net();
        let c = Checkpoint::from_net(&net);
        let path = std::env::temp_dir().join("singa_ckpt_test.bin");
        c.save(&path).unwrap();
        let c2 = Checkpoint::load(&path).unwrap();
        assert_eq!(c, c2);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_corrupt_input() {
        assert!(Checkpoint::read_from(&mut &b"JUNK"[..]).is_err());
        assert!(Checkpoint::read_from(&mut &b"SNGA\x63\x00\x00\x00"[..]).is_err());
        // truncated payload
        let net = small_net();
        let c = Checkpoint::from_net(&net);
        let mut buf = Vec::new();
        c.write_to(&mut buf).unwrap();
        buf.truncate(buf.len() - 7);
        assert!(Checkpoint::read_from(&mut buf.as_slice()).is_err());
    }

    /// Build a syntactically valid header by hand (magic, version, count,
    /// then caller-supplied entry bytes) — the corrupt-input fuzz corpus.
    fn header(count: u32, entries: &[u8]) -> Vec<u8> {
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.extend_from_slice(&count.to_le_bytes());
        buf.extend_from_slice(entries);
        buf
    }

    /// One tensor entry's header bytes: name, rank, dims — no payload.
    fn entry(name: &str, dims: &[u64]) -> Vec<u8> {
        let mut e = Vec::new();
        e.extend_from_slice(&(name.len() as u32).to_le_bytes());
        e.extend_from_slice(name.as_bytes());
        e.extend_from_slice(&(dims.len() as u32).to_le_bytes());
        for &d in dims {
            e.extend_from_slice(&d.to_le_bytes());
        }
        e
    }

    /// A header claiming ~4 billion params must be rejected up front —
    /// never trusted into a `with_capacity` or a 4-billion-entry loop.
    #[test]
    fn rejects_huge_param_count() {
        let buf = header(u32::MAX, &[]);
        let err = Checkpoint::read_from(&mut buf.as_slice()).unwrap_err();
        assert!(err.to_string().contains("param count"), "{err}");
    }

    /// Dims whose product wraps around usize (2^33 × 2^33 ≡ 4 mod 2^64)
    /// used to slip past the `n > 1 << 30` guard in release builds and
    /// read garbage as a tiny tensor; checked multiplication rejects it.
    #[test]
    fn rejects_product_wrapping_shape() {
        let buf = header(1, &entry("w", &[1 << 33, 1 << 33]));
        let err = Checkpoint::read_from(&mut buf.as_slice()).unwrap_err();
        assert!(err.to_string().contains("overflows"), "{err}");
    }

    /// A single dim beyond usize (on any platform, u64::MAX) is rejected
    /// at conversion, before any multiplication.
    #[test]
    fn rejects_dim_overflowing_usize() {
        let buf = header(1, &entry("w", &[u64::MAX, 2]));
        let err = Checkpoint::read_from(&mut buf.as_slice()).unwrap_err();
        assert!(err.to_string().contains("overflows"), "{err}");
    }

    /// In-range product above the element cap is still implausible.
    #[test]
    fn rejects_oversized_tensor_claim() {
        let buf = header(1, &entry("w", &[(1 << 30) + 1]));
        let err = Checkpoint::read_from(&mut buf.as_slice()).unwrap_err();
        assert!(err.to_string().contains("implausible tensor size"), "{err}");
    }

    /// A plausible-sized claim (256 MiB of f32s) backed by 8 bytes of
    /// payload must fail on the truncated read — quickly, with memory
    /// bounded by the delivered bytes plus one read chunk, not by the
    /// claimed size (the old code reserved the full claim up front).
    #[test]
    fn truncated_payload_with_large_claim_errors_cheaply() {
        let mut buf = header(1, &entry("w", &[1 << 26]));
        buf.extend_from_slice(&[0u8; 8]); // 2 of the claimed 2^26 floats
        assert!(Checkpoint::read_from(&mut buf.as_slice()).is_err());
    }

    /// Shape-mismatched restore is an error naming the offending param —
    /// not a panic (the recovery path feeds untrusted files through this).
    #[test]
    fn try_restore_shape_mismatch_names_param() {
        let net = small_net();
        let mut c = Checkpoint::from_net(&net);
        c.tensors.insert("fc/weight".to_string(), Blob::zeros(&[7, 7]));
        let mut fresh = small_net();
        let err = c.try_restore(&mut fresh).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("fc/weight"), "error must name the param: {msg}");
        assert!(msg.contains("shape mismatch"), "{msg}");
    }

    /// The thin `restore` wrapper keeps the historical panicking contract.
    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn restore_wrapper_panics_on_shape_mismatch() {
        let net = small_net();
        let mut c = Checkpoint::from_net(&net);
        c.tensors.insert("fc/weight".to_string(), Blob::zeros(&[7, 7]));
        c.restore(&mut small_net());
    }

    /// `try_restore` matches by name: a checkpoint missing a param restores
    /// the rest and reports the count.
    #[test]
    fn try_restore_partial_by_name() {
        let net = small_net();
        let mut c = Checkpoint::from_net(&net);
        c.tensors.remove("fc/bias");
        let mut fresh = NetBuilder::new()
            .add(LayerConf::new("data", LayerKind::Input { shape: vec![2, 4] }, &[]))
            .add(LayerConf::new(
                "fc",
                LayerKind::InnerProduct { out: 3, act: Activation::Tanh, init_std: 0.2 },
                &["data"],
            ))
            .build(&mut Rng::new(99));
        assert_eq!(c.try_restore(&mut fresh).unwrap(), 1);
        let want = net.params().iter().find(|p| p.name == "fc/weight").unwrap().data.clone();
        let got = fresh.params().iter().find(|p| p.name == "fc/weight").unwrap().data.clone();
        assert_eq!(want, got);
    }

    /// Restoring into an identically-shaped net copies in place: zero Blob
    /// allocations (the old `p.data = v.clone()` allocated per param).
    #[test]
    fn restore_in_place_is_allocation_free() {
        let net = small_net();
        let c = Checkpoint::from_net(&net);
        let mut fresh = small_net();
        let before = Blob::alloc_count();
        assert_eq!(c.try_restore(&mut fresh).unwrap(), 2);
        assert_eq!(Blob::alloc_count(), before, "in-place restore must not allocate");
    }

    #[test]
    fn roundtrip_property_random_tensors() {
        forall(25, |g| {
            let mut c = Checkpoint::new();
            let count = g.usize(0, 5);
            for i in 0..count {
                let r = g.usize(1, 3);
                let shape: Vec<usize> = (0..r).map(|_| g.usize(1, 6)).collect();
                let n: usize = shape.iter().product();
                c.tensors.insert(format!("p{i}"), Blob::from_vec(&shape, g.f32_vec(n, -5.0, 5.0)));
            }
            let mut buf = Vec::new();
            c.write_to(&mut buf).unwrap();
            let c2 = Checkpoint::read_from(&mut buf.as_slice()).unwrap();
            prop_assert(c == c2, "roundtrip")
        });
    }
}
