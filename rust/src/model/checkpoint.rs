//! Model checkpointing: serialize parameter tensors to a versioned binary
//! file and restore them into (possibly different) nets by name — the
//! mechanism the paper's deep auto-encoder uses to port RBM weights between
//! training stages ("the parameters trained from the first RBM are ported,
//! through checkpoint, into step 2", §4.2.2), and what a production job
//! needs for fault tolerance and warm starts.
//!
//! Format (little-endian):
//! ```text
//! magic "SNGA" | u32 version | u32 count |
//!   per param: u32 name_len | name bytes | u32 ndims | u64 dims... | f32 data...
//! ```

use crate::tensor::Blob;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"SNGA";
const VERSION: u32 = 1;

/// A named set of tensors (what gets saved/restored).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Checkpoint {
    pub tensors: HashMap<String, Blob>,
}

impl Checkpoint {
    pub fn new() -> Checkpoint {
        Checkpoint::default()
    }

    /// Capture all parameters of a net (by `Param::name`).
    pub fn from_net(net: &crate::model::NeuralNet) -> Checkpoint {
        let mut c = Checkpoint::new();
        for p in net.params() {
            c.tensors.insert(p.name.clone(), p.data.clone());
        }
        c
    }

    /// Restore into a net: every param whose name matches (and whose shape
    /// agrees) is overwritten. Returns the number restored.
    pub fn restore(&self, net: &mut crate::model::NeuralNet) -> usize {
        let mut n = 0;
        for p in net.params_mut() {
            if let Some(v) = self.tensors.get(&p.name) {
                assert_eq!(
                    v.shape(),
                    p.data.shape(),
                    "checkpoint shape mismatch for {}",
                    p.name
                );
                p.data = v.clone();
                n += 1;
            }
        }
        n
    }

    /// Serialize to a writer.
    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        // Sort for determinism.
        let mut names: Vec<&String> = self.tensors.keys().collect();
        names.sort();
        for name in names {
            let blob = &self.tensors[name];
            w.write_all(&(name.len() as u32).to_le_bytes())?;
            w.write_all(name.as_bytes())?;
            w.write_all(&(blob.shape().len() as u32).to_le_bytes())?;
            for &d in blob.shape() {
                w.write_all(&(d as u64).to_le_bytes())?;
            }
            for &v in blob.data() {
                w.write_all(&v.to_le_bytes())?;
            }
        }
        Ok(())
    }

    /// Deserialize from a reader.
    pub fn read_from<R: Read>(r: &mut R) -> Result<Checkpoint> {
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(anyhow!("not a singa checkpoint (bad magic)"));
        }
        let version = read_u32(r)?;
        if version != VERSION {
            return Err(anyhow!("unsupported checkpoint version {version}"));
        }
        let count = read_u32(r)? as usize;
        let mut tensors = HashMap::with_capacity(count);
        for _ in 0..count {
            let name_len = read_u32(r)? as usize;
            if name_len > 4096 {
                return Err(anyhow!("implausible name length {name_len}"));
            }
            let mut name = vec![0u8; name_len];
            r.read_exact(&mut name)?;
            let name = String::from_utf8(name).map_err(|_| anyhow!("non-utf8 param name"))?;
            let ndims = read_u32(r)? as usize;
            if ndims > 16 {
                return Err(anyhow!("implausible rank {ndims}"));
            }
            let mut shape = Vec::with_capacity(ndims);
            for _ in 0..ndims {
                let mut b = [0u8; 8];
                r.read_exact(&mut b)?;
                shape.push(u64::from_le_bytes(b) as usize);
            }
            let n: usize = shape.iter().product();
            if n > 1 << 30 {
                return Err(anyhow!("implausible tensor size {n}"));
            }
            let mut data = Vec::with_capacity(n);
            let mut buf = [0u8; 4];
            for _ in 0..n {
                r.read_exact(&mut buf)?;
                data.push(f32::from_le_bytes(buf));
            }
            tensors.insert(name, Blob::from_vec(&shape, data));
        }
        Ok(Checkpoint { tensors })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        self.write_to(&mut f)
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        Checkpoint::read_from(&mut f)
    }

    /// Total bytes of tensor payload.
    pub fn byte_size(&self) -> usize {
        self.tensors.values().map(|b| b.byte_size()).sum()
    }
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layer::{Activation, LayerConf, LayerKind};
    use crate::model::NetBuilder;
    use crate::utils::quickcheck::{forall, prop_assert};
    use crate::utils::rng::Rng;

    fn small_net() -> crate::model::NeuralNet {
        NetBuilder::new()
            .add(LayerConf::new("data", LayerKind::Input { shape: vec![2, 4] }, &[]))
            .add(LayerConf::new(
                "fc",
                LayerKind::InnerProduct { out: 3, act: Activation::Tanh, init_std: 0.2 },
                &["data"],
            ))
            .build(&mut Rng::new(5))
    }

    #[test]
    fn roundtrip_in_memory() {
        let net = small_net();
        let c = Checkpoint::from_net(&net);
        let mut buf = Vec::new();
        c.write_to(&mut buf).unwrap();
        let c2 = Checkpoint::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(c, c2);
        assert_eq!(c.byte_size(), (4 * 3 + 3) * 4);
    }

    #[test]
    fn restore_into_fresh_net() {
        let net = small_net();
        let c = Checkpoint::from_net(&net);
        let mut fresh = NetBuilder::new()
            .add(LayerConf::new("data", LayerKind::Input { shape: vec![2, 4] }, &[]))
            .add(LayerConf::new(
                "fc",
                LayerKind::InnerProduct { out: 3, act: Activation::Tanh, init_std: 0.2 },
                &["data"],
            ))
            .build(&mut Rng::new(99)); // different init
        let restored = c.restore(&mut fresh);
        assert_eq!(restored, 2);
        for (a, b) in net.params().iter().zip(fresh.params()) {
            assert_eq!(a.data, b.data);
        }
    }

    #[test]
    fn partial_restore_by_name() {
        let net = small_net();
        let mut c = Checkpoint::from_net(&net);
        c.tensors.remove("fc/bias");
        let mut fresh = small_net();
        assert_eq!(c.restore(&mut fresh), 1);
    }

    #[test]
    fn file_roundtrip() {
        let net = small_net();
        let c = Checkpoint::from_net(&net);
        let path = std::env::temp_dir().join("singa_ckpt_test.bin");
        c.save(&path).unwrap();
        let c2 = Checkpoint::load(&path).unwrap();
        assert_eq!(c, c2);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_corrupt_input() {
        assert!(Checkpoint::read_from(&mut &b"JUNK"[..]).is_err());
        assert!(Checkpoint::read_from(&mut &b"SNGA\x63\x00\x00\x00"[..]).is_err());
        // truncated payload
        let net = small_net();
        let c = Checkpoint::from_net(&net);
        let mut buf = Vec::new();
        c.write_to(&mut buf).unwrap();
        buf.truncate(buf.len() - 7);
        assert!(Checkpoint::read_from(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn roundtrip_property_random_tensors() {
        forall(25, |g| {
            let mut c = Checkpoint::new();
            let count = g.usize(0, 5);
            for i in 0..count {
                let r = g.usize(1, 3);
                let shape: Vec<usize> = (0..r).map(|_| g.usize(1, 6)).collect();
                let n: usize = shape.iter().product();
                c.tensors.insert(format!("p{i}"), Blob::from_vec(&shape, g.f32_vec(n, -5.0, 5.0)));
            }
            let mut buf = Vec::new();
            c.write_to(&mut buf).unwrap();
            let c2 = Checkpoint::read_from(&mut buf.as_slice()).unwrap();
            prop_assert(c == c2, "roundtrip")
        });
    }
}
