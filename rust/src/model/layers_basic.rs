//! Basic built-in layers: input, inner-product, activations, dropout, and
//! the connection layers the partitioner inserts (slice / concat / split /
//! bridge). Paper Table II.

use super::layer::{Activation, Layer, Phase};
use crate::tensor::blob::Param;
use crate::tensor::{ops, Blob};
use crate::utils::rng::Rng;
use std::any::Any;

/// Input layer: the training loop copies its mini-batch straight into the
/// layer's workspace slot each iteration (`NeuralNet::set_input_ref`), so
/// forward only checks the slot was actually fed (the paper's data/parser
/// layers; loading is in [`crate::data`]).
pub struct InputLayer {
    name: String,
    shape: Vec<usize>,
    fed: bool,
}

impl InputLayer {
    pub fn new(name: &str, shape: Vec<usize>) -> InputLayer {
        InputLayer { name: name.to_string(), shape, fed: false }
    }

    /// Called by `NeuralNet::set_input_ref` when a batch lands in the slot.
    pub(crate) fn mark_fed(&mut self) {
        self.fed = true;
    }
}

impl Layer for InputLayer {
    fn name(&self) -> &str {
        &self.name
    }

    fn type_name(&self) -> &'static str {
        "Input"
    }

    fn setup(&mut self, _src: &[&[usize]], _rng: &mut Rng) -> Vec<usize> {
        self.shape.clone()
    }

    fn compute_feature(&mut self, _phase: Phase, _srcs: &[&Blob], _out: &mut Blob) {
        // The workspace slot holds the batch copied in by set_input; keep
        // the old allocate-per-call contract's guard against running a net
        // whose input was never fed (silent all-zeros batches otherwise).
        assert!(self.fed, "InputLayer '{}': set_input not called", self.name);
    }

    fn compute_gradient(
        &mut self,
        _srcs: &[&Blob],
        _own: &Blob,
        _grad: Option<&Blob>,
        _src_grads: &mut [Option<&mut Blob>],
    ) {
    }

    fn as_any(&mut self) -> &mut dyn Any {
        self
    }
}

/// Fully-connected layer `y = act(x W + b)` — the paper's running example
/// (Fig 4c): ComputeFeature rotates (multiply W), shifts (plus b), applies
/// the nonlinearity; ComputeGradient produces dW, db and dx.
pub struct InnerProductLayer {
    name: String,
    out: usize,
    act: Activation,
    init_std: f32,
    pub(super) weight: Param,
    pub(super) bias: Param,
    /// When dim-1 partitioned: (start, count, total) of the output columns
    /// this sub-layer owns (paper Fig 12).
    col_slice: Option<(usize, usize, usize)>,
    /// Reusable backward scratch for the activation-chained `dy`.
    dy_scratch: Blob,
}

impl InnerProductLayer {
    pub fn new(name: &str, out: usize, act: Activation, init_std: f32) -> InnerProductLayer {
        InnerProductLayer {
            name: name.to_string(),
            out,
            act,
            init_std,
            weight: Param::new(&format!("{name}/weight"), Blob::zeros(&[0])),
            bias: Param::new(&format!("{name}/bias"), Blob::zeros(&[0])),
            col_slice: None,
            dy_scratch: Blob::default(),
        }
    }

    /// Slice this layer's parameters for feature-dimension (dim 1)
    /// partitioning: keep output columns `[start, start+count)` (paper
    /// Fig 12: both W and b are split per sub-layer).
    pub fn set_out_slice(&mut self, start: usize, count: usize, total: usize) {
        assert!(start + count <= total);
        self.out = count;
        self.col_slice = Some((start, count, total));
    }
}

impl Layer for InnerProductLayer {
    fn name(&self) -> &str {
        &self.name
    }

    fn type_name(&self) -> &'static str {
        "InnerProduct"
    }

    fn setup(&mut self, src_shapes: &[&[usize]], rng: &mut Rng) -> Vec<usize> {
        assert_eq!(src_shapes.len(), 1, "{}: InnerProduct wants 1 src", self.name);
        let in_dim: usize = src_shapes[0][1..].iter().product();
        let batch = src_shapes[0][0];
        self.weight =
            Param::new(&format!("{}/weight", self.name), Blob::gaussian(&[in_dim, self.out], self.init_std, rng));
        self.bias = Param::new(&format!("{}/bias", self.name), Blob::zeros(&[self.out]))
            .with_lr_mult(2.0)
            .with_wd_mult(0.0);
        vec![batch, self.out]
    }

    fn compute_feature(&mut self, _phase: Phase, srcs: &[&Blob], out: &mut Blob) {
        // The blob's matrix view already flattens trailing dims, so no
        // reshape copy is needed: x is [batch, in_dim] as far as GEMM cares.
        let x = srcs[0];
        out.resize(&[x.rows(), self.out]);
        ops::matmul_into(x, &self.weight.data, out, 0.0);
        ops::add_row_vec(out, &self.bias.data);
        // In-place fused activation: producer (pre-activation) and consumer
        // share the workspace slot.
        match self.act {
            Activation::Identity => {}
            Activation::Sigmoid => ops::sigmoid_inplace(out),
            Activation::Tanh => ops::tanh_inplace(out),
            Activation::Relu => ops::relu_inplace(out),
        }
    }

    fn compute_gradient(
        &mut self,
        srcs: &[&Blob],
        own: &Blob,
        grad_out: Option<&Blob>,
        src_grads: &mut [Option<&mut Blob>],
    ) {
        let dy_post = grad_out.expect("InnerProduct needs an output gradient");
        // Chain through the fused activation into reusable scratch
        // (Identity borrows the upstream gradient directly).
        let dy: &Blob = match self.act {
            Activation::Identity => dy_post,
            Activation::Sigmoid => {
                ops::zip_into(own, dy_post, &mut self.dy_scratch, ops::dsigmoid);
                &self.dy_scratch
            }
            Activation::Tanh => {
                ops::zip_into(own, dy_post, &mut self.dy_scratch, ops::dtanh);
                &self.dy_scratch
            }
            Activation::Relu => {
                ops::zip_into(own, dy_post, &mut self.dy_scratch, ops::drelu_from_out);
                &self.dy_scratch
            }
        };
        let x = srcs[0];
        // dW += x^T dy ; db += colsum(dy) ; dx += dy W^T
        ops::matmul_tn_into(x, dy, &mut self.weight.grad, 1.0);
        ops::sum_rows_into(dy, &mut self.bias.grad, true);
        if let Some(dx) = &mut src_grads[0] {
            ops::matmul_nt_into(dy, &self.weight.data, dx, 1.0);
        }
    }

    fn params(&self) -> Vec<&Param> {
        vec![&self.weight, &self.bias]
    }

    fn params_mut(&mut self) -> Vec<&mut Param> {
        vec![&mut self.weight, &mut self.bias]
    }

    fn as_any(&mut self) -> &mut dyn Any {
        self
    }
}

impl InnerProductLayer {
    /// Column-slice metadata for dim-1 partitioned sub-layers.
    pub fn col_slice(&self) -> Option<(usize, usize, usize)> {
        self.col_slice
    }
}

/// Standalone activation layer. Forward writes straight from the source
/// slot into the output slot (identical shapes — the "in-place" elementwise
/// family); backward derives `dx` from the stored OUTPUT, so no input cache
/// is kept at all.
pub struct ActivationLayer {
    name: String,
    act: Activation,
}

impl ActivationLayer {
    pub fn new(name: &str, act: Activation) -> ActivationLayer {
        ActivationLayer { name: name.to_string(), act }
    }
}

impl Layer for ActivationLayer {
    fn name(&self) -> &str {
        &self.name
    }

    fn type_name(&self) -> &'static str {
        "Activation"
    }

    fn setup(&mut self, src_shapes: &[&[usize]], _rng: &mut Rng) -> Vec<usize> {
        src_shapes[0].to_vec()
    }

    fn compute_feature(&mut self, _phase: Phase, srcs: &[&Blob], out: &mut Blob) {
        match self.act {
            Activation::Identity => out.copy_from(srcs[0]),
            Activation::Sigmoid => ops::sigmoid_into(srcs[0], out),
            Activation::Tanh => ops::tanh_into(srcs[0], out),
            Activation::Relu => ops::relu_into(srcs[0], out),
        }
    }

    fn compute_gradient(
        &mut self,
        _srcs: &[&Blob],
        own: &Blob,
        grad_out: Option<&Blob>,
        src_grads: &mut [Option<&mut Blob>],
    ) {
        let dy = grad_out.expect("Activation needs grad");
        let dx = src_grads[0].as_mut().expect("Activation src slot");
        match self.act {
            Activation::Identity => dx.add_assign(dy),
            Activation::Sigmoid => ops::zip_acc(own, dy, dx, ops::dsigmoid),
            Activation::Tanh => ops::zip_acc(own, dy, dx, ops::dtanh),
            Activation::Relu => ops::zip_acc(own, dy, dx, ops::drelu_from_out),
        }
    }

    fn as_any(&mut self) -> &mut dyn Any {
        self
    }
}

/// Inverted dropout: at train time scale kept units by 1/keep so test-time
/// forward is the identity.
pub struct DropoutLayer {
    name: String,
    keep: f32,
    mask: Blob,
    rng: Rng,
}

impl DropoutLayer {
    pub fn new(name: &str, keep: f32) -> DropoutLayer {
        assert!(keep > 0.0 && keep <= 1.0, "keep probability in (0,1]");
        DropoutLayer {
            name: name.to_string(),
            keep,
            mask: Blob::default(),
            rng: Rng::new(0x0d0d + name.len() as u64),
        }
    }
}

impl Layer for DropoutLayer {
    fn name(&self) -> &str {
        &self.name
    }

    fn type_name(&self) -> &'static str {
        "Dropout"
    }

    fn setup(&mut self, src_shapes: &[&[usize]], _rng: &mut Rng) -> Vec<usize> {
        src_shapes[0].to_vec()
    }

    fn compute_feature(&mut self, phase: Phase, srcs: &[&Blob], out: &mut Blob) {
        if phase == Phase::Test {
            out.copy_from(srcs[0]);
            return;
        }
        // Refill the persistent mask in place (reallocates only when the
        // batch shape changes).
        let scale = 1.0 / self.keep;
        self.mask.resize(srcs[0].shape());
        let (keep, rng) = (self.keep, &mut self.rng);
        for m in self.mask.data_mut() {
            *m = if rng.uniform() < keep { scale } else { 0.0 };
        }
        ops::zip_into(srcs[0], &self.mask, out, |x, m| x * m);
    }

    fn compute_gradient(
        &mut self,
        _srcs: &[&Blob],
        _own: &Blob,
        grad_out: Option<&Blob>,
        src_grads: &mut [Option<&mut Blob>],
    ) {
        let dy = grad_out.expect("Dropout needs grad");
        let dx = src_grads[0].as_mut().expect("Dropout src slot");
        ops::zip_acc(dy, &self.mask, dx, |d, m| d * m);
    }

    fn as_any(&mut self) -> &mut dyn Any {
        self
    }
}

// ---------------- Connection layers (paper §5.3) ----------------

/// SliceLayer: emits one slice of its source along `dim`. The partitioner
/// creates `parts` SliceLayers over the same source; the backward pass
/// produces a gradient covering only this slice, which the net accumulates
/// into the source gradient at the right offset.
pub struct SliceLayer {
    name: String,
    dim: usize,
    parts: usize,
    index: usize,
}

impl SliceLayer {
    pub fn new(name: &str, dim: usize, parts: usize, index: usize) -> SliceLayer {
        assert!(dim <= 1, "slice dim must be 0 or 1");
        assert!(index < parts);
        SliceLayer { name: name.to_string(), dim, parts, index }
    }

    /// `(start, count)` of this part, derived from the RUNTIME source shape
    /// so batch-size changes at evaluation time keep slicing correctly.
    fn range_for(&self, src: &Blob) -> (usize, usize) {
        let total = if self.dim == 0 { src.rows() } else { src.cols() };
        Blob::split_range(total, self.parts, self.index)
    }
}

impl Layer for SliceLayer {
    fn name(&self) -> &str {
        &self.name
    }

    fn type_name(&self) -> &'static str {
        "Slice"
    }

    fn setup(&mut self, src_shapes: &[&[usize]], _rng: &mut Rng) -> Vec<usize> {
        let s = src_shapes[0];
        let total = if self.dim == 0 { s[0] } else { s[1..].iter().product() };
        let range = Blob::split_range(total, self.parts, self.index);
        if self.dim == 0 {
            let mut out = s.to_vec();
            out[0] = range.1;
            out
        } else {
            vec![s[0], range.1]
        }
    }

    fn compute_feature(&mut self, _phase: Phase, srcs: &[&Blob], out: &mut Blob) {
        let src = srcs[0];
        let (start, count) = self.range_for(src);
        let cols = src.cols();
        if self.dim == 0 {
            let mut shape = src.shape().to_vec();
            shape[0] = count;
            out.resize(&shape);
            out.data_mut().copy_from_slice(&src.data()[start * cols..(start + count) * cols]);
        } else {
            out.resize(&[src.rows(), count]);
            for r in 0..src.rows() {
                let base = r * cols + start;
                out.data_mut()[r * count..(r + 1) * count]
                    .copy_from_slice(&src.data()[base..base + count]);
            }
        }
    }

    fn compute_gradient(
        &mut self,
        srcs: &[&Blob],
        _own: &Blob,
        grad_out: Option<&Blob>,
        src_grads: &mut [Option<&mut Blob>],
    ) {
        let dy = grad_out.expect("Slice needs grad");
        let (start, count) = self.range_for(srcs[0]);
        // Accumulate the slice gradient into its range of the (pre-zeroed,
        // possibly shared) source slot.
        let dx = src_grads[0].as_mut().expect("Slice src slot");
        let cols = srcs[0].cols();
        if self.dim == 0 {
            for (d, s) in dx.data_mut()[start * cols..(start + count) * cols]
                .iter_mut()
                .zip(dy.data())
            {
                *d += s;
            }
        } else {
            for r in 0..srcs[0].rows() {
                let base = r * cols + start;
                for (d, s) in dx.data_mut()[base..base + count]
                    .iter_mut()
                    .zip(&dy.data()[r * count..(r + 1) * count])
                {
                    *d += s;
                }
            }
        }
    }

    fn is_connection(&self) -> bool {
        true
    }

    fn as_any(&mut self) -> &mut dyn Any {
        self
    }
}

/// ConcatLayer: concatenates all sources along `dim`; backward slices the
/// gradient back out into each source's slot. Row/column extents come from
/// the runtime source shapes, so no per-build state is cached.
pub struct ConcatLayer {
    name: String,
    dim: usize,
}

impl ConcatLayer {
    pub fn new(name: &str, dim: usize) -> ConcatLayer {
        assert!(dim <= 1);
        ConcatLayer { name: name.to_string(), dim }
    }
}

impl Layer for ConcatLayer {
    fn name(&self) -> &str {
        &self.name
    }

    fn type_name(&self) -> &'static str {
        "Concat"
    }

    fn setup(&mut self, src_shapes: &[&[usize]], _rng: &mut Rng) -> Vec<usize> {
        assert!(!src_shapes.is_empty());
        if self.dim == 0 {
            let rows: usize = src_shapes.iter().map(|s| s[0]).sum();
            let mut out = src_shapes[0].to_vec();
            out[0] = rows;
            out
        } else {
            let cols: usize = src_shapes
                .iter()
                .map(|s| s[1..].iter().product::<usize>())
                .sum();
            vec![src_shapes[0][0], cols]
        }
    }

    fn compute_feature(&mut self, _phase: Phase, srcs: &[&Blob], out: &mut Blob) {
        if self.dim == 0 {
            let rows: usize = srcs.iter().map(|s| s.rows()).sum();
            let cols = srcs[0].cols();
            let mut shape = srcs[0].shape().to_vec();
            shape[0] = rows;
            out.resize(&shape);
            let mut offset = 0;
            for src in srcs {
                assert_eq!(src.cols(), cols, "concat_rows column mismatch");
                out.data_mut()[offset..offset + src.len()].copy_from_slice(src.data());
                offset += src.len();
            }
        } else {
            let rows = srcs[0].rows();
            let total_cols: usize = srcs.iter().map(|s| s.cols()).sum();
            out.resize(&[rows, total_cols]);
            let mut col_off = 0;
            for src in srcs {
                assert_eq!(src.rows(), rows, "concat_cols row mismatch");
                let c = src.cols();
                for r in 0..rows {
                    out.data_mut()[r * total_cols + col_off..r * total_cols + col_off + c]
                        .copy_from_slice(&src.data()[r * c..(r + 1) * c]);
                }
                col_off += c;
            }
        }
    }

    fn compute_gradient(
        &mut self,
        srcs: &[&Blob],
        _own: &Blob,
        grad_out: Option<&Blob>,
        src_grads: &mut [Option<&mut Blob>],
    ) {
        let dy = grad_out.expect("Concat needs grad");
        if self.dim == 0 {
            let mut offset = 0;
            for (src, slot) in srcs.iter().zip(src_grads.iter_mut()) {
                let n = src.len();
                if let Some(dx) = slot.as_mut() {
                    for (d, s) in dx.data_mut().iter_mut().zip(&dy.data()[offset..offset + n]) {
                        *d += s;
                    }
                }
                offset += n;
            }
        } else {
            let rows = srcs[0].rows();
            let total_cols = dy.cols();
            let mut col_off = 0;
            for (src, slot) in srcs.iter().zip(src_grads.iter_mut()) {
                let c = src.cols();
                if let Some(dx) = slot.as_mut() {
                    for r in 0..rows {
                        let drow = &mut dx.data_mut()[r * c..(r + 1) * c];
                        let srow = &dy.data()[r * total_cols + col_off..r * total_cols + col_off + c];
                        for (d, s) in drow.iter_mut().zip(srow) {
                            *d += s;
                        }
                    }
                }
                col_off += c;
            }
        }
    }

    fn is_connection(&self) -> bool {
        true
    }

    fn as_any(&mut self) -> &mut dyn Any {
        self
    }
}

/// SplitLayer: identity forward to multiple consumers; the net accumulates
/// (sums) consumer gradients before calling `compute_gradient`, so backward
/// is the identity too.
pub struct SplitLayer {
    name: String,
}

impl SplitLayer {
    pub fn new(name: &str) -> SplitLayer {
        SplitLayer { name: name.to_string() }
    }
}

impl Layer for SplitLayer {
    fn name(&self) -> &str {
        &self.name
    }

    fn type_name(&self) -> &'static str {
        "Split"
    }

    fn setup(&mut self, src_shapes: &[&[usize]], _rng: &mut Rng) -> Vec<usize> {
        src_shapes[0].to_vec()
    }

    fn compute_feature(&mut self, _phase: Phase, srcs: &[&Blob], out: &mut Blob) {
        out.copy_from(srcs[0]);
    }

    fn compute_gradient(
        &mut self,
        _srcs: &[&Blob],
        _own: &Blob,
        grad_out: Option<&Blob>,
        src_grads: &mut [Option<&mut Blob>],
    ) {
        let dy = grad_out.expect("Split needs grad");
        src_grads[0].as_mut().expect("Split src slot").add_assign(dy);
    }

    fn is_connection(&self) -> bool {
        true
    }

    fn as_any(&mut self) -> &mut dyn Any {
        self
    }
}

/// Bridge layers (paper Fig 14): a BridgeSrc/BridgeDst pair transfers a
/// feature (and its gradient, in reverse) between sub-layers placed on
/// different workers. In-process they are pass-through, but they carry the
/// location boundary: the coordinator accounts transferred bytes and, in
/// virtual-time mode, charges the link cost; `BridgeSrc::compute_feature`
/// is where the paper's asynchronous send is initiated.
pub struct BridgeLayer {
    name: String,
    is_src: bool,
    /// Bytes moved in the most recent forward (for the comm ledger).
    pub last_bytes: usize,
}

impl BridgeLayer {
    pub fn new_src(name: &str) -> BridgeLayer {
        BridgeLayer { name: name.to_string(), is_src: true, last_bytes: 0 }
    }

    pub fn new_dst(name: &str) -> BridgeLayer {
        BridgeLayer { name: name.to_string(), is_src: false, last_bytes: 0 }
    }
}

impl Layer for BridgeLayer {
    fn name(&self) -> &str {
        &self.name
    }

    fn type_name(&self) -> &'static str {
        if self.is_src {
            "BridgeSrc"
        } else {
            "BridgeDst"
        }
    }

    fn setup(&mut self, src_shapes: &[&[usize]], _rng: &mut Rng) -> Vec<usize> {
        src_shapes[0].to_vec()
    }

    fn compute_feature(&mut self, _phase: Phase, srcs: &[&Blob], out: &mut Blob) {
        self.last_bytes = srcs[0].byte_size();
        out.copy_from(srcs[0]);
    }

    fn compute_gradient(
        &mut self,
        _srcs: &[&Blob],
        _own: &Blob,
        grad_out: Option<&Blob>,
        src_grads: &mut [Option<&mut Blob>],
    ) {
        let dy = grad_out.expect("Bridge needs grad");
        src_grads[0].as_mut().expect("Bridge src slot").add_assign(dy);
    }

    fn is_connection(&self) -> bool {
        true
    }

    fn as_any(&mut self) -> &mut dyn Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::test_support::{backward, forward};
    use crate::utils::quickcheck::{forall, prop_close};

    fn rng() -> Rng {
        Rng::new(42)
    }

    #[test]
    fn input_layer_roundtrip() {
        // Input features flow through the net's workspace slot.
        use crate::model::layer::{LayerConf, LayerKind};
        use crate::model::NetBuilder;
        let mut l = InputLayer::new("data", vec![2, 3]);
        assert_eq!(l.setup(&[], &mut rng()), vec![2, 3]);
        let mut net = NetBuilder::new()
            .add(LayerConf::new("data", LayerKind::Input { shape: vec![2, 3] }, &[]))
            .build(&mut rng());
        let b = Blob::full(&[2, 3], 7.0);
        net.set_input_ref("data", &b);
        net.forward(Phase::Train);
        assert_eq!(net.feature("data"), &b);
    }

    #[test]
    fn inner_product_shapes() {
        let mut l = InnerProductLayer::new("fc", 5, Activation::Identity, 0.1);
        let out = l.setup(&[&[4, 3]], &mut rng());
        assert_eq!(out, vec![4, 5]);
        assert_eq!(l.params().len(), 2);
        assert_eq!(l.params()[0].data.shape(), &[3, 5]);
        assert_eq!(l.params()[1].data.shape(), &[5]);
    }

    #[test]
    fn inner_product_gradcheck() {
        // Scalar objective f = sum(ip(x)); check dW, db, dx numerically.
        for act in [Activation::Identity, Activation::Sigmoid, Activation::Tanh] {
            let mut l = InnerProductLayer::new("fc", 4, act, 0.3);
            l.setup(&[&[3, 5]], &mut rng());
            let mut r = Rng::new(9);
            let x = Blob::from_vec(&[3, 5], r.uniform_vec(15, -1.0, 1.0));
            let y = forward(&mut l, Phase::Train, &[&x]);
            let dy = Blob::full(y.shape(), 1.0);
            let grads = backward(&mut l, &[&x], &y, Some(&dy));
            let dx = grads[0].clone().unwrap();

            let eps = 1e-2;
            let f = |l: &mut InnerProductLayer, x: &Blob| -> f32 {
                forward(l, Phase::Train, &[x]).sum()
            };
            for i in 0..x.len() {
                let mut p = x.clone();
                p.data_mut()[i] += eps;
                let mut m = x.clone();
                m.data_mut()[i] -= eps;
                let num = (f(&mut l, &p) - f(&mut l, &m)) / (2.0 * eps);
                assert!(
                    (num - dx.data()[i]).abs() < 2e-2,
                    "{act:?} dx[{i}] {num} vs {}",
                    dx.data()[i]
                );
            }
            // dW numeric
            let wlen = l.weight.data.len();
            for i in (0..wlen).step_by((wlen / 8).max(1)) {
                let orig = l.weight.data.data()[i];
                l.weight.data.data_mut()[i] = orig + eps;
                let fp = f(&mut l, &x);
                l.weight.data.data_mut()[i] = orig - eps;
                let fm = f(&mut l, &x);
                l.weight.data.data_mut()[i] = orig;
                let num = (fp - fm) / (2.0 * eps);
                assert!(
                    (num - l.weight.grad.data()[i]).abs() < 2e-2,
                    "{act:?} dW[{i}] {num} vs {}",
                    l.weight.grad.data()[i]
                );
            }
        }
    }

    #[test]
    fn relu_inner_product_grad() {
        let mut l = InnerProductLayer::new("fc", 3, Activation::Relu, 0.5);
        l.setup(&[&[2, 3]], &mut rng());
        let mut r = Rng::new(4);
        let x = Blob::from_vec(&[2, 3], r.uniform_vec(6, -1.0, 1.0));
        let y = forward(&mut l, Phase::Train, &[&x]);
        let dy = Blob::full(y.shape(), 1.0);
        let grads = backward(&mut l, &[&x], &y, Some(&dy));
        assert!(grads[0].is_some());
        // outputs that are exactly 0 must receive zero activation grad
        for (i, &v) in y.data().iter().enumerate() {
            if v == 0.0 {
                // contribution of this unit to dx is zero; weaker check: bias grad
                let _ = i;
            }
        }
    }

    #[test]
    fn dropout_train_vs_test() {
        let mut l = DropoutLayer::new("drop", 0.6);
        l.setup(&[&[1, 1000]], &mut rng());
        let x = Blob::full(&[1, 1000], 1.0);
        let test = forward(&mut l, Phase::Test, &[&x]);
        assert_eq!(test, x);
        let train = forward(&mut l, Phase::Train, &[&x]);
        let kept = train.data().iter().filter(|&&v| v > 0.0).count();
        assert!((kept as f32 / 1000.0 - 0.6).abs() < 0.08, "kept {kept}");
        // kept units scaled by 1/keep
        for &v in train.data() {
            assert!(v == 0.0 || (v - 1.0 / 0.6).abs() < 1e-6);
        }
        // backward uses the same mask
        let dy = Blob::full(&[1, 1000], 1.0);
        let dx = backward(&mut l, &[&x], &train, Some(&dy))[0].clone().unwrap();
        for (a, b) in dx.data().iter().zip(train.data()) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn slice_concat_roundtrip_dim0() {
        forall(30, |g| {
            let rows = g.usize(2, 10);
            let cols = g.usize(1, 6);
            let parts = g.usize(1, rows.min(4));
            let x = Blob::from_vec(&[rows, cols], g.f32_vec(rows * cols, -1.0, 1.0));
            let mut outs = Vec::new();
            for i in 0..parts {
                let mut sl = SliceLayer::new(&format!("s{i}"), 0, parts, i);
                sl.setup(&[&[rows, cols]], &mut rng());
                outs.push(forward(&mut sl, Phase::Train, &[&x]));
            }
            let mut cat = ConcatLayer::new("c", 0);
            let shapes: Vec<&[usize]> = outs.iter().map(|o| o.shape()).collect();
            cat.setup(&shapes, &mut rng());
            let refs: Vec<&Blob> = outs.iter().collect();
            let back = forward(&mut cat, Phase::Train, &refs);
            prop_close(back.data(), x.data(), 0.0, 0.0, "roundtrip")
        });
    }

    #[test]
    fn slice_backward_scatters() {
        let x = Blob::from_vec(&[2, 4], (0..8).map(|v| v as f32).collect());
        let mut sl = SliceLayer::new("s", 1, 2, 1);
        sl.setup(&[&[2, 4]], &mut rng());
        let y = forward(&mut sl, Phase::Train, &[&x]);
        assert_eq!(y.data(), &[2., 3., 6., 7.]);
        let dy = Blob::full(&[2, 2], 1.0);
        let dx = backward(&mut sl, &[&x], &y, Some(&dy))[0].clone().unwrap();
        assert_eq!(dx.data(), &[0., 0., 1., 1., 0., 0., 1., 1.]);
    }

    #[test]
    fn concat_backward_slices() {
        let a = Blob::full(&[2, 2], 1.0);
        let b = Blob::full(&[2, 3], 2.0);
        let mut cat = ConcatLayer::new("c", 1);
        cat.setup(&[&[2, 2], &[2, 3]], &mut rng());
        let y = forward(&mut cat, Phase::Train, &[&a, &b]);
        assert_eq!(y.shape(), &[2, 5]);
        let dy = Blob::from_vec(&[2, 5], (0..10).map(|v| v as f32).collect());
        let gs = backward(&mut cat, &[&a, &b], &y, Some(&dy));
        assert_eq!(gs[0].as_ref().unwrap().data(), &[0., 1., 5., 6.]);
        assert_eq!(gs[1].as_ref().unwrap().data(), &[2., 3., 4., 7., 8., 9.]);
    }

    #[test]
    fn bridge_accounts_bytes() {
        let mut b = BridgeLayer::new_src("b");
        b.setup(&[&[4, 4]], &mut rng());
        let x = Blob::zeros(&[4, 4]);
        let y = forward(&mut b, Phase::Train, &[&x]);
        assert_eq!(y, x);
        assert_eq!(b.last_bytes, 64);
        assert!(b.is_connection());
    }

    /// Direct layer-level check of the accumulate contract: two successive
    /// backward calls into the same slot must sum.
    #[test]
    fn compute_gradient_accumulates_into_slot() {
        let mut l = ActivationLayer::new("a", Activation::Identity);
        l.setup(&[&[2, 2]], &mut rng());
        let x = Blob::full(&[2, 2], 1.0);
        let y = forward(&mut l, Phase::Train, &[&x]);
        let dy = Blob::full(&[2, 2], 3.0);
        let mut slot = Blob::full(&[2, 2], 1.0); // pre-existing contribution
        {
            let mut refs = [Some(&mut slot)];
            l.compute_gradient(&[&x], &y, Some(&dy), &mut refs);
        }
        assert_eq!(slot.data(), &[4.0; 4], "identity backward must +=");
    }
}
