//! Basic built-in layers: input, inner-product, activations, dropout, and
//! the connection layers the partitioner inserts (slice / concat / split /
//! bridge). Paper Table II.

use super::layer::{Activation, Layer, Phase};
use crate::tensor::blob::Param;
use crate::tensor::{ops, Blob};
use crate::utils::rng::Rng;
use std::any::Any;

/// Input layer: the training loop sets its mini-batch blob each iteration
/// (the paper's data/parser layers; loading is in [`crate::data`]).
pub struct InputLayer {
    name: String,
    shape: Vec<usize>,
    batch: Option<Blob>,
}

impl InputLayer {
    pub fn new(name: &str, shape: Vec<usize>) -> InputLayer {
        InputLayer { name: name.to_string(), shape, batch: None }
    }

    /// Feed the next mini-batch.
    pub fn set_batch(&mut self, b: Blob) {
        self.batch = Some(b);
    }
}

impl Layer for InputLayer {
    fn name(&self) -> &str {
        &self.name
    }

    fn type_name(&self) -> &'static str {
        "Input"
    }

    fn setup(&mut self, _src: &[&[usize]], _rng: &mut Rng) -> Vec<usize> {
        self.shape.clone()
    }

    fn compute_feature(&mut self, _phase: Phase, _srcs: &[&Blob]) -> Blob {
        self.batch.clone().expect("InputLayer: set_batch not called")
    }

    fn compute_gradient(
        &mut self,
        _srcs: &[&Blob],
        _own: &Blob,
        _grad: Option<&Blob>,
    ) -> Vec<Option<Blob>> {
        Vec::new()
    }

    fn as_any(&mut self) -> &mut dyn Any {
        self
    }
}

/// Fully-connected layer `y = act(x W + b)` — the paper's running example
/// (Fig 4c): ComputeFeature rotates (multiply W), shifts (plus b), applies
/// the nonlinearity; ComputeGradient produces dW, db and dx.
pub struct InnerProductLayer {
    name: String,
    out: usize,
    act: Activation,
    init_std: f32,
    pub(super) weight: Param,
    pub(super) bias: Param,
    /// When dim-1 partitioned: (start, count, total) of the output columns
    /// this sub-layer owns (paper Fig 12).
    col_slice: Option<(usize, usize, usize)>,
}

impl InnerProductLayer {
    pub fn new(name: &str, out: usize, act: Activation, init_std: f32) -> InnerProductLayer {
        InnerProductLayer {
            name: name.to_string(),
            out,
            act,
            init_std,
            weight: Param::new(&format!("{name}/weight"), Blob::zeros(&[0])),
            bias: Param::new(&format!("{name}/bias"), Blob::zeros(&[0])),
            col_slice: None,
        }
    }

    /// Slice this layer's parameters for feature-dimension (dim 1)
    /// partitioning: keep output columns `[start, start+count)` (paper
    /// Fig 12: both W and b are split per sub-layer).
    pub fn set_out_slice(&mut self, start: usize, count: usize, total: usize) {
        assert!(start + count <= total);
        self.out = count;
        self.col_slice = Some((start, count, total));
    }
}

impl Layer for InnerProductLayer {
    fn name(&self) -> &str {
        &self.name
    }

    fn type_name(&self) -> &'static str {
        "InnerProduct"
    }

    fn setup(&mut self, src_shapes: &[&[usize]], rng: &mut Rng) -> Vec<usize> {
        assert_eq!(src_shapes.len(), 1, "{}: InnerProduct wants 1 src", self.name);
        let in_dim: usize = src_shapes[0][1..].iter().product();
        let batch = src_shapes[0][0];
        self.weight =
            Param::new(&format!("{}/weight", self.name), Blob::gaussian(&[in_dim, self.out], self.init_std, rng));
        self.bias = Param::new(&format!("{}/bias", self.name), Blob::zeros(&[self.out]))
            .with_lr_mult(2.0)
            .with_wd_mult(0.0);
        vec![batch, self.out]
    }

    fn compute_feature(&mut self, _phase: Phase, srcs: &[&Blob]) -> Blob {
        let x = srcs[0];
        let batch = x.rows();
        let x2 = x.reshape(&[batch, x.cols()]);
        let mut y = ops::matmul(&x2, &self.weight.data);
        ops::add_row_vec(&mut y, &self.bias.data);
        let out = match self.act {
            Activation::Identity => y,
            Activation::Sigmoid => ops::sigmoid(&y),
            Activation::Tanh => ops::tanh(&y),
            Activation::Relu => ops::relu(&y),
        };
        out
    }

    fn compute_gradient(
        &mut self,
        srcs: &[&Blob],
        own: &Blob,
        grad_out: Option<&Blob>,
    ) -> Vec<Option<Blob>> {
        let dy_post = grad_out.expect("InnerProduct needs an output gradient");
        // Chain through the fused activation.
        let dy = match self.act {
            Activation::Identity => dy_post.clone(),
            Activation::Sigmoid => ops::sigmoid_grad(own, dy_post),
            Activation::Tanh => ops::tanh_grad(own, dy_post),
            Activation::Relu => {
                // own stores post-relu output; relu'(x) = 1 where output > 0.
                ops::zip(own, dy_post, |y, d| if y > 0.0 { d } else { 0.0 })
            }
        };
        let x = srcs[0];
        let batch = x.rows();
        let x2 = x.reshape(&[batch, x.cols()]);
        // dW += x^T dy ; db += colsum(dy) ; dx = dy W^T
        self.weight.grad.add_assign(&ops::matmul_tn(&x2, &dy));
        self.bias.grad.add_assign(&ops::sum_rows(&dy));
        let dx = ops::matmul_nt(&dy, &self.weight.data);
        vec![Some(dx.reshape(x.shape()))]
    }

    fn params(&self) -> Vec<&Param> {
        vec![&self.weight, &self.bias]
    }

    fn params_mut(&mut self) -> Vec<&mut Param> {
        vec![&mut self.weight, &mut self.bias]
    }

    fn as_any(&mut self) -> &mut dyn Any {
        self
    }
}

impl InnerProductLayer {
    /// Column-slice metadata for dim-1 partitioned sub-layers.
    pub fn col_slice(&self) -> Option<(usize, usize, usize)> {
        self.col_slice
    }
}

/// Standalone activation layer.
pub struct ActivationLayer {
    name: String,
    act: Activation,
    input_cache: Blob,
}

impl ActivationLayer {
    pub fn new(name: &str, act: Activation) -> ActivationLayer {
        ActivationLayer { name: name.to_string(), act, input_cache: Blob::zeros(&[0]) }
    }
}

impl Layer for ActivationLayer {
    fn name(&self) -> &str {
        &self.name
    }

    fn type_name(&self) -> &'static str {
        "Activation"
    }

    fn setup(&mut self, src_shapes: &[&[usize]], _rng: &mut Rng) -> Vec<usize> {
        src_shapes[0].to_vec()
    }

    fn compute_feature(&mut self, _phase: Phase, srcs: &[&Blob]) -> Blob {
        self.input_cache = srcs[0].clone();
        match self.act {
            Activation::Identity => srcs[0].clone(),
            Activation::Sigmoid => ops::sigmoid(srcs[0]),
            Activation::Tanh => ops::tanh(srcs[0]),
            Activation::Relu => ops::relu(srcs[0]),
        }
    }

    fn compute_gradient(
        &mut self,
        _srcs: &[&Blob],
        own: &Blob,
        grad_out: Option<&Blob>,
    ) -> Vec<Option<Blob>> {
        let dy = grad_out.expect("Activation needs grad");
        let dx = match self.act {
            Activation::Identity => dy.clone(),
            Activation::Sigmoid => ops::sigmoid_grad(own, dy),
            Activation::Tanh => ops::tanh_grad(own, dy),
            Activation::Relu => ops::relu_grad(&self.input_cache, dy),
        };
        vec![Some(dx)]
    }

    fn as_any(&mut self) -> &mut dyn Any {
        self
    }
}

/// Inverted dropout: at train time scale kept units by 1/keep so test-time
/// forward is the identity.
pub struct DropoutLayer {
    name: String,
    keep: f32,
    mask: Blob,
    rng: Rng,
}

impl DropoutLayer {
    pub fn new(name: &str, keep: f32) -> DropoutLayer {
        assert!(keep > 0.0 && keep <= 1.0, "keep probability in (0,1]");
        DropoutLayer {
            name: name.to_string(),
            keep,
            mask: Blob::zeros(&[0]),
            rng: Rng::new(0x0d0d + name.len() as u64),
        }
    }
}

impl Layer for DropoutLayer {
    fn name(&self) -> &str {
        &self.name
    }

    fn type_name(&self) -> &'static str {
        "Dropout"
    }

    fn setup(&mut self, src_shapes: &[&[usize]], _rng: &mut Rng) -> Vec<usize> {
        src_shapes[0].to_vec()
    }

    fn compute_feature(&mut self, phase: Phase, srcs: &[&Blob]) -> Blob {
        if phase == Phase::Test {
            return srcs[0].clone();
        }
        let scale = 1.0 / self.keep;
        let mask = Blob::from_vec(
            srcs[0].shape(),
            (0..srcs[0].len())
                .map(|_| if self.rng.uniform() < self.keep { scale } else { 0.0 })
                .collect(),
        );
        let out = ops::zip(srcs[0], &mask, |x, m| x * m);
        self.mask = mask;
        out
    }

    fn compute_gradient(
        &mut self,
        _srcs: &[&Blob],
        _own: &Blob,
        grad_out: Option<&Blob>,
    ) -> Vec<Option<Blob>> {
        let dy = grad_out.expect("Dropout needs grad");
        vec![Some(ops::zip(dy, &self.mask, |d, m| d * m))]
    }

    fn as_any(&mut self) -> &mut dyn Any {
        self
    }
}

// ---------------- Connection layers (paper §5.3) ----------------

/// SliceLayer: emits one slice of its source along `dim`. The partitioner
/// creates `parts` SliceLayers over the same source; the backward pass
/// produces a gradient covering only this slice, which the net accumulates
/// into the source gradient at the right offset.
pub struct SliceLayer {
    name: String,
    dim: usize,
    parts: usize,
    index: usize,
    range: (usize, usize),
    src_shape: Vec<usize>,
}

impl SliceLayer {
    pub fn new(name: &str, dim: usize, parts: usize, index: usize) -> SliceLayer {
        assert!(dim <= 1, "slice dim must be 0 or 1");
        assert!(index < parts);
        SliceLayer {
            name: name.to_string(),
            dim,
            parts,
            index,
            range: (0, 0),
            src_shape: Vec::new(),
        }
    }
}

impl Layer for SliceLayer {
    fn name(&self) -> &str {
        &self.name
    }

    fn type_name(&self) -> &'static str {
        "Slice"
    }

    fn setup(&mut self, src_shapes: &[&[usize]], _rng: &mut Rng) -> Vec<usize> {
        let s = src_shapes[0];
        self.src_shape = s.to_vec();
        let total = if self.dim == 0 { s[0] } else { s[1..].iter().product() };
        self.range = Blob::split_points(total, self.parts)[self.index];
        if self.dim == 0 {
            let mut out = s.to_vec();
            out[0] = self.range.1;
            out
        } else {
            vec![s[0], self.range.1]
        }
    }

    fn compute_feature(&mut self, _phase: Phase, srcs: &[&Blob]) -> Blob {
        let (start, count) = self.range;
        if self.dim == 0 {
            srcs[0].slice_rows(start, count)
        } else {
            srcs[0].slice_cols(start, count)
        }
    }

    fn compute_gradient(
        &mut self,
        srcs: &[&Blob],
        _own: &Blob,
        grad_out: Option<&Blob>,
    ) -> Vec<Option<Blob>> {
        let dy = grad_out.expect("Slice needs grad");
        let (start, count) = self.range;
        // Scatter the slice gradient into a zero blob of the source shape.
        let mut dx = Blob::zeros(srcs[0].shape());
        if self.dim == 0 {
            let cols = srcs[0].cols();
            dx.data_mut()[start * cols..(start + count) * cols].copy_from_slice(dy.data());
        } else {
            let cols = srcs[0].cols();
            for r in 0..srcs[0].rows() {
                dx.data_mut()[r * cols + start..r * cols + start + count]
                    .copy_from_slice(&dy.data()[r * count..(r + 1) * count]);
            }
        }
        vec![Some(dx)]
    }

    fn is_connection(&self) -> bool {
        true
    }

    fn as_any(&mut self) -> &mut dyn Any {
        self
    }
}

/// ConcatLayer: concatenates all sources along `dim`; backward slices the
/// gradient back out per source.
pub struct ConcatLayer {
    name: String,
    dim: usize,
    src_cols: Vec<usize>,
    src_rows: Vec<usize>,
}

impl ConcatLayer {
    pub fn new(name: &str, dim: usize) -> ConcatLayer {
        assert!(dim <= 1);
        ConcatLayer { name: name.to_string(), dim, src_cols: Vec::new(), src_rows: Vec::new() }
    }
}

impl Layer for ConcatLayer {
    fn name(&self) -> &str {
        &self.name
    }

    fn type_name(&self) -> &'static str {
        "Concat"
    }

    fn setup(&mut self, src_shapes: &[&[usize]], _rng: &mut Rng) -> Vec<usize> {
        assert!(!src_shapes.is_empty());
        self.src_rows = src_shapes.iter().map(|s| s[0]).collect();
        self.src_cols = src_shapes.iter().map(|s| s[1..].iter().product()).collect();
        if self.dim == 0 {
            let rows: usize = self.src_rows.iter().sum();
            let mut out = src_shapes[0].to_vec();
            out[0] = rows;
            out
        } else {
            let cols: usize = self.src_cols.iter().sum();
            vec![src_shapes[0][0], cols]
        }
    }

    fn compute_feature(&mut self, _phase: Phase, srcs: &[&Blob]) -> Blob {
        if self.dim == 0 {
            Blob::concat_rows(srcs)
        } else {
            Blob::concat_cols(srcs)
        }
    }

    fn compute_gradient(
        &mut self,
        srcs: &[&Blob],
        _own: &Blob,
        grad_out: Option<&Blob>,
    ) -> Vec<Option<Blob>> {
        let dy = grad_out.expect("Concat needs grad");
        let mut out = Vec::with_capacity(srcs.len());
        let mut offset = 0;
        for (i, src) in srcs.iter().enumerate() {
            let g = if self.dim == 0 {
                let rows = self.src_rows[i];
                let g = dy.slice_rows(offset, rows);
                offset += rows;
                g.reshape(src.shape())
            } else {
                let cols = self.src_cols[i];
                let g = dy.slice_cols(offset, cols);
                offset += cols;
                g.reshape(src.shape())
            };
            out.push(Some(g));
        }
        out
    }

    fn is_connection(&self) -> bool {
        true
    }

    fn as_any(&mut self) -> &mut dyn Any {
        self
    }
}

/// SplitLayer: identity forward to multiple consumers; the net accumulates
/// (sums) consumer gradients before calling `compute_gradient`, so backward
/// is the identity too.
pub struct SplitLayer {
    name: String,
}

impl SplitLayer {
    pub fn new(name: &str) -> SplitLayer {
        SplitLayer { name: name.to_string() }
    }
}

impl Layer for SplitLayer {
    fn name(&self) -> &str {
        &self.name
    }

    fn type_name(&self) -> &'static str {
        "Split"
    }

    fn setup(&mut self, src_shapes: &[&[usize]], _rng: &mut Rng) -> Vec<usize> {
        src_shapes[0].to_vec()
    }

    fn compute_feature(&mut self, _phase: Phase, srcs: &[&Blob]) -> Blob {
        srcs[0].clone()
    }

    fn compute_gradient(
        &mut self,
        _srcs: &[&Blob],
        _own: &Blob,
        grad_out: Option<&Blob>,
    ) -> Vec<Option<Blob>> {
        vec![Some(grad_out.expect("Split needs grad").clone())]
    }

    fn is_connection(&self) -> bool {
        true
    }

    fn as_any(&mut self) -> &mut dyn Any {
        self
    }
}

/// Bridge layers (paper Fig 14): a BridgeSrc/BridgeDst pair transfers a
/// feature (and its gradient, in reverse) between sub-layers placed on
/// different workers. In-process they are pass-through, but they carry the
/// location boundary: the coordinator accounts transferred bytes and, in
/// virtual-time mode, charges the link cost; `BridgeSrc::compute_feature`
/// is where the paper's asynchronous send is initiated.
pub struct BridgeLayer {
    name: String,
    is_src: bool,
    /// Bytes moved in the most recent forward (for the comm ledger).
    pub last_bytes: usize,
}

impl BridgeLayer {
    pub fn new_src(name: &str) -> BridgeLayer {
        BridgeLayer { name: name.to_string(), is_src: true, last_bytes: 0 }
    }

    pub fn new_dst(name: &str) -> BridgeLayer {
        BridgeLayer { name: name.to_string(), is_src: false, last_bytes: 0 }
    }
}

impl Layer for BridgeLayer {
    fn name(&self) -> &str {
        &self.name
    }

    fn type_name(&self) -> &'static str {
        if self.is_src {
            "BridgeSrc"
        } else {
            "BridgeDst"
        }
    }

    fn setup(&mut self, src_shapes: &[&[usize]], _rng: &mut Rng) -> Vec<usize> {
        src_shapes[0].to_vec()
    }

    fn compute_feature(&mut self, _phase: Phase, srcs: &[&Blob]) -> Blob {
        self.last_bytes = srcs[0].byte_size();
        srcs[0].clone()
    }

    fn compute_gradient(
        &mut self,
        _srcs: &[&Blob],
        _own: &Blob,
        grad_out: Option<&Blob>,
    ) -> Vec<Option<Blob>> {
        vec![Some(grad_out.expect("Bridge needs grad").clone())]
    }

    fn is_connection(&self) -> bool {
        true
    }

    fn as_any(&mut self) -> &mut dyn Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::utils::quickcheck::{forall, prop_close};

    fn rng() -> Rng {
        Rng::new(42)
    }

    #[test]
    fn input_layer_roundtrip() {
        let mut l = InputLayer::new("data", vec![2, 3]);
        assert_eq!(l.setup(&[], &mut rng()), vec![2, 3]);
        let b = Blob::full(&[2, 3], 7.0);
        l.set_batch(b.clone());
        let out = l.compute_feature(Phase::Train, &[]);
        assert_eq!(out, b);
    }

    #[test]
    fn inner_product_shapes() {
        let mut l = InnerProductLayer::new("fc", 5, Activation::Identity, 0.1);
        let out = l.setup(&[&[4, 3]], &mut rng());
        assert_eq!(out, vec![4, 5]);
        assert_eq!(l.params().len(), 2);
        assert_eq!(l.params()[0].data.shape(), &[3, 5]);
        assert_eq!(l.params()[1].data.shape(), &[5]);
    }

    #[test]
    fn inner_product_gradcheck() {
        // Scalar objective f = sum(ip(x)); check dW, db, dx numerically.
        for act in [Activation::Identity, Activation::Sigmoid, Activation::Tanh] {
            let mut l = InnerProductLayer::new("fc", 4, act, 0.3);
            l.setup(&[&[3, 5]], &mut rng());
            let mut r = Rng::new(9);
            let x = Blob::from_vec(&[3, 5], r.uniform_vec(15, -1.0, 1.0));
            let y = l.compute_feature(Phase::Train, &[&x]);
            let dy = Blob::full(y.shape(), 1.0);
            let grads = l.compute_gradient(&[&x], &y, Some(&dy));
            let dx = grads[0].clone().unwrap();

            let eps = 1e-2;
            let f = |l: &mut InnerProductLayer, x: &Blob| -> f32 {
                l.compute_feature(Phase::Train, &[&x.clone()]).sum()
            };
            for i in 0..x.len() {
                let mut p = x.clone();
                p.data_mut()[i] += eps;
                let mut m = x.clone();
                m.data_mut()[i] -= eps;
                let num = (f(&mut l, &p) - f(&mut l, &m)) / (2.0 * eps);
                assert!(
                    (num - dx.data()[i]).abs() < 2e-2,
                    "{act:?} dx[{i}] {num} vs {}",
                    dx.data()[i]
                );
            }
            // dW numeric
            let wlen = l.weight.data.len();
            for i in (0..wlen).step_by((wlen / 8).max(1)) {
                let orig = l.weight.data.data()[i];
                l.weight.data.data_mut()[i] = orig + eps;
                let fp = f(&mut l, &x);
                l.weight.data.data_mut()[i] = orig - eps;
                let fm = f(&mut l, &x);
                l.weight.data.data_mut()[i] = orig;
                let num = (fp - fm) / (2.0 * eps);
                assert!(
                    (num - l.weight.grad.data()[i]).abs() < 2e-2,
                    "{act:?} dW[{i}] {num} vs {}",
                    l.weight.grad.data()[i]
                );
            }
        }
    }

    #[test]
    fn relu_inner_product_grad() {
        let mut l = InnerProductLayer::new("fc", 3, Activation::Relu, 0.5);
        l.setup(&[&[2, 3]], &mut rng());
        let mut r = Rng::new(4);
        let x = Blob::from_vec(&[2, 3], r.uniform_vec(6, -1.0, 1.0));
        let y = l.compute_feature(Phase::Train, &[&x]);
        let dy = Blob::full(y.shape(), 1.0);
        let grads = l.compute_gradient(&[&x], &y, Some(&dy));
        assert!(grads[0].is_some());
        // outputs that are exactly 0 must receive zero activation grad
        for (i, &v) in y.data().iter().enumerate() {
            if v == 0.0 {
                // contribution of this unit to dx is zero; weaker check: bias grad
                let _ = i;
            }
        }
    }

    #[test]
    fn dropout_train_vs_test() {
        let mut l = DropoutLayer::new("drop", 0.6);
        l.setup(&[&[1, 1000]], &mut rng());
        let x = Blob::full(&[1, 1000], 1.0);
        let test = l.compute_feature(Phase::Test, &[&x]);
        assert_eq!(test, x);
        let train = l.compute_feature(Phase::Train, &[&x]);
        let kept = train.data().iter().filter(|&&v| v > 0.0).count();
        assert!((kept as f32 / 1000.0 - 0.6).abs() < 0.08, "kept {kept}");
        // kept units scaled by 1/keep
        for &v in train.data() {
            assert!(v == 0.0 || (v - 1.0 / 0.6).abs() < 1e-6);
        }
        // backward uses the same mask
        let dy = Blob::full(&[1, 1000], 1.0);
        let dx = l.compute_gradient(&[&x], &train, Some(&dy))[0].clone().unwrap();
        for (a, b) in dx.data().iter().zip(train.data()) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn slice_concat_roundtrip_dim0() {
        forall(30, |g| {
            let rows = g.usize(2, 10);
            let cols = g.usize(1, 6);
            let parts = g.usize(1, rows.min(4));
            let x = Blob::from_vec(&[rows, cols], g.f32_vec(rows * cols, -1.0, 1.0));
            let mut outs = Vec::new();
            for i in 0..parts {
                let mut sl = SliceLayer::new(&format!("s{i}"), 0, parts, i);
                sl.setup(&[&[rows, cols]], &mut rng());
                outs.push(sl.compute_feature(Phase::Train, &[&x]));
            }
            let mut cat = ConcatLayer::new("c", 0);
            let shapes: Vec<&[usize]> = outs.iter().map(|o| o.shape()).collect();
            cat.setup(&shapes, &mut rng());
            let refs: Vec<&Blob> = outs.iter().collect();
            let back = cat.compute_feature(Phase::Train, &refs);
            prop_close(back.data(), x.data(), 0.0, 0.0, "roundtrip")
        });
    }

    #[test]
    fn slice_backward_scatters() {
        let x = Blob::from_vec(&[2, 4], (0..8).map(|v| v as f32).collect());
        let mut sl = SliceLayer::new("s", 1, 2, 1);
        sl.setup(&[&[2, 4]], &mut rng());
        let y = sl.compute_feature(Phase::Train, &[&x]);
        assert_eq!(y.data(), &[2., 3., 6., 7.]);
        let dy = Blob::full(&[2, 2], 1.0);
        let dx = sl.compute_gradient(&[&x], &y, Some(&dy))[0].clone().unwrap();
        assert_eq!(dx.data(), &[0., 0., 1., 1., 0., 0., 1., 1.]);
    }

    #[test]
    fn concat_backward_slices() {
        let a = Blob::full(&[2, 2], 1.0);
        let b = Blob::full(&[2, 3], 2.0);
        let mut cat = ConcatLayer::new("c", 1);
        cat.setup(&[&[2, 2], &[2, 3]], &mut rng());
        let y = cat.compute_feature(Phase::Train, &[&a, &b]);
        assert_eq!(y.shape(), &[2, 5]);
        let dy = Blob::from_vec(&[2, 5], (0..10).map(|v| v as f32).collect());
        let gs = cat.compute_gradient(&[&a, &b], &y, Some(&dy));
        assert_eq!(gs[0].as_ref().unwrap().data(), &[0., 1., 5., 6.]);
        assert_eq!(gs[1].as_ref().unwrap().data(), &[2., 3., 4., 7., 8., 9.]);
    }

    #[test]
    fn bridge_accounts_bytes() {
        let mut b = BridgeLayer::new_src("b");
        b.setup(&[&[4, 4]], &mut rng());
        let x = Blob::zeros(&[4, 4]);
        let y = b.compute_feature(Phase::Train, &[&x]);
        assert_eq!(y, x);
        assert_eq!(b.last_bytes, 64);
        assert!(b.is_connection());
    }
}
