//! Job configuration files: the JSON front-end to [`crate::coordinator::JobConf`]
//! (the paper's "job configuration" a user submits, §3). Model presets keep
//! the file small; layer-level nets can be listed explicitly.
//!
//! ```json
//! {
//!   "name": "cifar-sync",
//!   "model": "cifar_convnet",
//!   "batch": 64,
//!   "iters": 200,
//!   "updater": {"algo": "sgd", "lr": 0.05, "momentum": 0.9},
//!   "cluster": {"worker_groups": 1, "workers_per_group": 4,
//!                "server_groups": 1, "servers_per_group": 1}
//! }
//! ```

use crate::cluster::ClusterTopology;
use crate::coordinator::JobConf;
use crate::updater::{Algo, LrSchedule, UpdaterConf};
use crate::utils::json::Json;
use anyhow::{anyhow, Result};

/// Parse a job configuration document.
pub fn parse_job(text: &str) -> Result<JobConf> {
    let doc = Json::parse(text).map_err(|e| anyhow!("config: {e}"))?;
    let name = doc.get("name").and_then(Json::as_str).unwrap_or("job").to_string();
    let batch = doc.get("batch").and_then(Json::as_usize).unwrap_or(16);
    let model = doc.get("model").and_then(Json::as_str).unwrap_or("mlp");
    let net = model_preset(model, batch)?;

    let mut conf = JobConf::new(&name, net);
    conf.batch_size = batch;
    conf.iters = doc.get("iters").and_then(Json::as_usize).unwrap_or(100) as u64;
    if let Some(seed) = doc.get("seed").and_then(Json::as_usize) {
        conf.seed = seed as u64;
    }
    if let Some(u) = doc.get("updater") {
        conf.updater = parse_updater(u)?;
    }
    if let Some(c) = doc.get("cluster") {
        conf.topology = parse_cluster(c);
    }
    if let Some(p) = doc.get("partition_within_group").and_then(Json::as_bool) {
        conf.partition_within_group = p;
    }
    if let Some(c) = doc.get("wire_codec").and_then(Json::as_str) {
        conf.wire_codec = crate::comm::Codec::parse(c)?;
    }
    if let Some(r) = doc.get("retry") {
        conf.retry = parse_retry(r)?;
    }
    Ok(conf)
}

/// Parse the optional `"retry"` block (wire-protocol timeout/backoff knobs,
/// see [`crate::comm::RetryConf`]). Wrong-typed fields fall back to their
/// defaults; semantically invalid values — a non-finite or non-positive
/// timeout, a backoff below 1, zero attempts — are errors here so the job
/// fails at parse time instead of panicking inside `run_job`.
fn parse_retry(r: &Json) -> Result<crate::comm::RetryConf> {
    let d = crate::comm::RetryConf::default();
    let timeout_us = r.get("timeout_us").and_then(Json::as_f64).unwrap_or(d.timeout_us);
    let backoff = r.get("backoff").and_then(Json::as_f64).unwrap_or(d.backoff);
    let max_attempts =
        r.get("max_attempts").and_then(Json::as_usize).unwrap_or(d.max_attempts as usize);
    if !timeout_us.is_finite() || timeout_us <= 0.0 {
        return Err(anyhow!("retry: timeout_us must be finite and > 0; got {timeout_us}"));
    }
    if !backoff.is_finite() || backoff < 1.0 {
        return Err(anyhow!("retry: backoff must be finite and >= 1; got {backoff}"));
    }
    if max_attempts == 0 || max_attempts > u32::MAX as usize {
        return Err(anyhow!("retry: max_attempts must be in 1..=2^32-1; got {max_attempts}"));
    }
    Ok(crate::comm::RetryConf { timeout_us, backoff, max_attempts: max_attempts as u32 })
}

/// Built-in model presets.
pub fn model_preset(name: &str, batch: usize) -> Result<crate::model::NetBuilder> {
    use crate::model::layer::{Activation, LayerConf, LayerKind};
    use crate::model::NetBuilder;
    match name {
        "mlp" => Ok(NetBuilder::new()
            .add(LayerConf::new("data", LayerKind::Input { shape: vec![batch, 784] }, &[]))
            .add(LayerConf::new("label", LayerKind::Input { shape: vec![batch] }, &[]))
            .add(LayerConf::new(
                "h1",
                LayerKind::InnerProduct { out: 128, act: Activation::Relu, init_std: 0.05 },
                &["data"],
            ))
            .add(LayerConf::new(
                "logits",
                LayerKind::InnerProduct { out: 10, act: Activation::Identity, init_std: 0.05 },
                &["h1"],
            ))
            .add(LayerConf::new("loss", LayerKind::SoftmaxLoss, &["logits", "label"]))),
        "cifar_convnet" => Ok(crate::bench::cifar_convnet(batch)),
        other => Err(anyhow!("unknown model preset '{other}' (mlp | cifar_convnet)")),
    }
}

fn parse_updater(u: &Json) -> Result<UpdaterConf> {
    let lr = u.get("lr").and_then(Json::as_f64).unwrap_or(0.1) as f32;
    let algo = match u.get("algo").and_then(Json::as_str).unwrap_or("sgd") {
        "sgd" => Algo::Sgd {
            momentum: u.get("momentum").and_then(Json::as_f64).unwrap_or(0.0) as f32,
        },
        "adagrad" => Algo::AdaGrad { eps: 1e-8 },
        "nesterov" => Algo::Nesterov {
            momentum: u.get("momentum").and_then(Json::as_f64).unwrap_or(0.9) as f32,
        },
        "rmsprop" => Algo::RmsProp {
            decay: u.get("decay").and_then(Json::as_f64).unwrap_or(0.9) as f32,
            eps: 1e-8,
        },
        other => return Err(anyhow!("unknown updater '{other}'")),
    };
    let schedule = match u.get("schedule").and_then(Json::as_str) {
        Some("step") => LrSchedule::Step {
            gamma: u.get("gamma").and_then(Json::as_f64).unwrap_or(0.1) as f32,
            stride: u.get("stride").and_then(Json::as_usize).unwrap_or(100) as u64,
        },
        Some("exp") => LrSchedule::Exp {
            gamma: u.get("gamma").and_then(Json::as_f64).unwrap_or(0.999) as f32,
        },
        _ => LrSchedule::Fixed,
    };
    Ok(UpdaterConf {
        algo,
        lr,
        schedule,
        weight_decay: u.get("weight_decay").and_then(Json::as_f64).unwrap_or(0.0) as f32,
    })
}

fn parse_cluster(c: &Json) -> ClusterTopology {
    ClusterTopology {
        nworker_groups: c.get("worker_groups").and_then(Json::as_usize).unwrap_or(1),
        nworkers_per_group: c.get("workers_per_group").and_then(Json::as_usize).unwrap_or(1),
        nserver_groups: c.get("server_groups").and_then(Json::as_usize).unwrap_or(1),
        nservers_per_group: c.get("servers_per_group").and_then(Json::as_usize).unwrap_or(1),
        group_sync_interval: c.get("sync_interval").and_then(Json::as_usize).unwrap_or(0) as u64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Framework;

    #[test]
    fn parse_full_job() {
        let conf = parse_job(
            r#"{
              "name": "t", "model": "mlp", "batch": 8, "iters": 5,
              "updater": {"algo": "sgd", "lr": 0.2, "momentum": 0.9,
                           "schedule": "step", "gamma": 0.5, "stride": 10},
              "cluster": {"worker_groups": 2, "workers_per_group": 1,
                           "server_groups": 1, "servers_per_group": 2}
            }"#,
        )
        .unwrap();
        assert_eq!(conf.batch_size, 8);
        assert_eq!(conf.iters, 5);
        assert_eq!(conf.topology.framework(), Some(Framework::Downpour));
        assert_eq!(conf.updater.lr, 0.2);
        assert!(matches!(conf.updater.algo, Algo::Sgd { momentum } if momentum == 0.9));
        assert!(matches!(conf.updater.schedule, LrSchedule::Step { .. }));
    }

    #[test]
    fn defaults_are_sane() {
        let conf = parse_job(r#"{"model": "mlp"}"#).unwrap();
        assert_eq!(conf.batch_size, 16);
        assert!(conf.topology.is_synchronous());
    }

    #[test]
    fn rejects_unknown_preset_and_updater() {
        assert!(parse_job(r#"{"model": "ghost"}"#).is_err());
        assert!(parse_job(r#"{"model": "mlp", "updater": {"algo": "warp"}}"#).is_err());
    }

    #[test]
    fn parses_wire_codec_and_rejects_unknown() {
        use crate::comm::Codec;
        let conf = parse_job(r#"{"model": "mlp"}"#).unwrap();
        assert_eq!(conf.wire_codec, Codec::Raw);
        let conf = parse_job(r#"{"model": "mlp", "wire_codec": "int8"}"#).unwrap();
        assert_eq!(conf.wire_codec, Codec::Int8);
        let conf = parse_job(r#"{"model": "mlp", "wire_codec": "f16"}"#).unwrap();
        assert_eq!(conf.wire_codec, Codec::F16);
        assert!(parse_job(r#"{"model": "mlp", "wire_codec": "zip"}"#).is_err());
    }

    #[test]
    fn parses_retry_knobs_with_defaults_and_rejects_invalid() {
        use crate::comm::RetryConf;
        // No block → defaults.
        let conf = parse_job(r#"{"model": "mlp"}"#).unwrap();
        assert_eq!(conf.retry, RetryConf::default());
        // Full block.
        let conf = parse_job(
            r#"{"model": "mlp",
                "retry": {"timeout_us": 900.0, "backoff": 1.5, "max_attempts": 6}}"#,
        )
        .unwrap();
        assert_eq!(conf.retry.timeout_us, 900.0);
        assert_eq!(conf.retry.backoff, 1.5);
        assert_eq!(conf.retry.max_attempts, 6);
        // Wrong-typed fields degrade to defaults (the house parsing style).
        let conf = parse_job(
            r#"{"model": "mlp", "retry": {"timeout_us": "slow", "backoff": null}}"#,
        )
        .unwrap();
        assert_eq!(conf.retry, RetryConf::default());
        // Semantically invalid values error at parse time, never panic.
        assert!(parse_job(r#"{"model": "mlp", "retry": {"timeout_us": 0}}"#).is_err());
        assert!(parse_job(r#"{"model": "mlp", "retry": {"timeout_us": -5.0}}"#).is_err());
        assert!(parse_job(r#"{"model": "mlp", "retry": {"backoff": 0.5}}"#).is_err());
        assert!(parse_job(r#"{"model": "mlp", "retry": {"max_attempts": 0}}"#).is_err());
    }

    #[test]
    fn malformed_documents_error_instead_of_panicking() {
        // Broken JSON surfaces the parse error with context, never a panic.
        assert!(parse_job("").is_err());
        assert!(parse_job("{").is_err());
        assert!(parse_job(r#"{"model": "mlp", "batch": 1e}"#).is_err());
        assert!(parse_job(r#"{"updater": {"algo": "sgd", "lr": }}"#).is_err());
    }

    #[test]
    fn wrong_typed_fields_fall_back_to_defaults() {
        // Fields of the wrong JSON type degrade to their defaults instead of
        // panicking mid-parse; only semantically invalid values are errors.
        let conf = parse_job(
            r#"{"model": "mlp", "batch": "many", "iters": null,
                "updater": {"algo": "sgd", "lr": "fast", "momentum": []},
                "cluster": {"worker_groups": "two"}}"#,
        )
        .unwrap();
        assert_eq!(conf.batch_size, 16);
        assert_eq!(conf.iters, 100);
        assert_eq!(conf.updater.lr, 0.1);
        assert_eq!(conf.topology.nworker_groups, 1);
    }
}
