//! Comparator-system policies (DESIGN.md §Substitutions).
//!
//! The paper benchmarks SINGA against Caffe, CXXNET, Petuum, Torch,
//! TensorFlow and MxNet. Those binaries are not available offline; the
//! paper itself attributes each system's behaviour to an identifiable
//! *policy* (op-level BLAS threading, tree reduction, sync copies, central
//! parameter server...), so each baseline here is that policy implemented
//! against the same measured workload profiles our own engine uses. The
//! figure shapes — who wins, where curves bend — follow from the policies.

use crate::comm::LinkModel;
use crate::coordinator::copyqueue::{
    iteration_time_us, CopyMode, LayerProfile, UpdateRates,
};

// ---------------------------------------------------------------------------
// Fig 18(a): single NUMA node, op-level vs worker-level parallelism
// ---------------------------------------------------------------------------

/// Multi-threaded-BLAS efficiency model: only a fraction of an iteration is
/// inside parallelizable kernels (Amdahl), thread efficiency decays with
/// contention, and crossing the 8-core socket boundary adds a cross-NUMA
/// memory penalty (the paper's observed >8-thread degradation, Fig 18a).
#[derive(Debug, Clone, Copy)]
pub struct OpParallelModel {
    /// Fraction of iteration time inside ops BLAS can parallelize.
    pub parallel_frac: f64,
    /// Per-extra-thread efficiency decay (contention).
    pub thread_eff: f64,
    /// Multiplier on the parallel part per thread beyond one socket.
    pub numa_penalty: f64,
    /// Cores per socket.
    pub socket: usize,
}

impl OpParallelModel {
    /// Caffe: O2 build, im2col+BLAS, moderate op coverage.
    pub fn caffe() -> OpParallelModel {
        OpParallelModel { parallel_frac: 0.70, thread_eff: 0.92, numa_penalty: 0.06, socket: 8 }
    }

    /// CXXNET: O3 + expression templates, slightly better coverage.
    pub fn cxxnet() -> OpParallelModel {
        OpParallelModel { parallel_frac: 0.74, thread_eff: 0.92, numa_penalty: 0.06, socket: 8 }
    }

    /// SINGA single worker with multi-threaded BLAS (the paper's "SINGA"
    /// curve in Fig 18a).
    pub fn singa_single() -> OpParallelModel {
        OpParallelModel { parallel_frac: 0.72, thread_eff: 0.93, numa_penalty: 0.06, socket: 8 }
    }

    /// Iteration time with `threads` BLAS threads, given the measured
    /// single-thread time.
    pub fn time_ms(&self, single_thread_ms: f64, threads: usize) -> f64 {
        let t = threads.max(1) as f64;
        // effective speedup of the parallel part
        let eff = self.thread_eff.powf(t - 1.0);
        let mut par = self.parallel_frac / (t * eff);
        if threads > self.socket {
            par *= 1.0 + self.numa_penalty * (threads - self.socket) as f64;
        }
        single_thread_ms * ((1.0 - self.parallel_frac) + par)
    }
}

/// SINGA-dist worker-level parallelism (Fig 18a): the mini-batch is
/// partitioned across workers, so the *whole* iteration parallelizes;
/// overheads are per-worker gradient aggregation plus scheduler cost, and
/// the same cross-socket penalty applies past 8 workers.
pub fn singa_dist_time_ms(single_thread_ms: f64, workers: usize, agg_ms_per_worker: f64) -> f64 {
    let w = workers.max(1) as f64;
    let mut t = single_thread_ms / w + agg_ms_per_worker * (w - 1.0).max(0.0);
    if workers > 8 {
        t *= 1.0 + 0.03 * (workers - 8) as f64;
    }
    t
}

// ---------------------------------------------------------------------------
// Fig 18(b): cluster synchronous scaling — AllReduce vs central PS (Petuum)
// ---------------------------------------------------------------------------

/// Synchronous cluster iteration time (ms) for SINGA's AllReduce layout:
/// compute splits across workers; each node-local server handles 1/nodes of
/// the parameters, so parameter traffic per node stays ~constant.
pub fn allreduce_cluster_time_ms(
    single_thread_ms: f64,
    workers: usize,
    nodes: usize,
    param_bytes: usize,
    net: &LinkModel,
) -> f64 {
    let compute = single_thread_ms / workers as f64;
    // each node sends/receives its shard to/from every other node once
    let shard = param_bytes / nodes.max(1);
    let comm_us = net.transfer_us(2 * shard) + 2.0 * net.latency_us * (nodes as f64).log2().max(1.0);
    compute + comm_us / 1e3
}

/// Petuum-style central parameter server: all workers' gradients funnel
/// through one server's ingress link; beyond the knee the server saturates
/// and time grows with worker count (the paper's observed degradation at
/// 128 workers).
pub fn central_ps_cluster_time_ms(
    single_thread_ms: f64,
    workers: usize,
    param_bytes: usize,
    net: &LinkModel,
) -> f64 {
    let compute = single_thread_ms / workers as f64;
    // server ingress serializes all gradient streams + sync barrier delay
    let ingress_us = net.transfer_us(param_bytes) * workers as f64 / 2.0; // 2 ingress lanes
    let barrier_us = net.latency_us * (workers as f64).sqrt();
    compute + (ingress_us + barrier_us) / 1e3
}

// ---------------------------------------------------------------------------
// Fig 21: multi-GPU throughput — per-system policies on the same profiles
// ---------------------------------------------------------------------------

/// A comparator system's multi-device policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SystemPolicy {
    /// SINGA: async copy queue + hybrid partitioning (fc traffic scales
    /// with batch, not params).
    Singa,
    /// Caffe: tree reduction; without peer-to-peer access all reductions
    /// stage through host memory (paper's explanation of the 3-GPU drop).
    CaffeTree,
    /// Torch: synchronous allreduce on device, no comm/compute overlap.
    TorchSync,
    /// TensorFlow: parameter server on host, synchronous copies.
    TfSyncPs,
    /// MxNet with AllreduceCPU: gradients aggregated on host, partial
    /// overlap (its dependency engine overlaps some transfers).
    MxnetCpuAllreduce,
}

impl SystemPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            SystemPolicy::Singa => "SINGA",
            SystemPolicy::CaffeTree => "Caffe",
            SystemPolicy::TorchSync => "Torch",
            SystemPolicy::TfSyncPs => "TensorFlow",
            SystemPolicy::MxnetCpuAllreduce => "MxNet",
        }
    }

    pub fn all() -> [SystemPolicy; 5] {
        [
            SystemPolicy::Singa,
            SystemPolicy::CaffeTree,
            SystemPolicy::TorchSync,
            SystemPolicy::TfSyncPs,
            SystemPolicy::MxnetCpuAllreduce,
        ]
    }

    /// Time of one synchronized multi-device iteration (µs) with
    /// `per_worker_batch` images per device.
    pub fn iteration_us(
        &self,
        profiles: &[LayerProfile],
        workers: usize,
        link: &LinkModel,
        rates: &UpdateRates,
    ) -> f64 {
        let param_bytes: usize = profiles.iter().map(|l| l.param_bytes).sum();
        let w = workers.max(1) as f64;
        if workers <= 1 {
            // Single device: every system keeps the whole SGD step on the
            // device (no cross-device traffic); only framework overhead
            // differs (paper: "on a single GPU the difference ... is not
            // significant" since all use cuDNN underneath).
            let base = iteration_time_us(profiles, CopyMode::NoCopy, link, rates);
            let overhead = match self {
                SystemPolicy::Singa => 1.00,
                SystemPolicy::TorchSync => 1.02,
                SystemPolicy::MxnetCpuAllreduce => 1.03,
                SystemPolicy::CaffeTree => 1.08,
                SystemPolicy::TfSyncPs => 1.12,
            };
            return base * overhead;
        }
        match self {
            SystemPolicy::Singa => {
                // async copy pipeline; aggregation bandwidth shared by w
                // devices but overlapped with compute.
                let base = iteration_time_us(profiles, CopyMode::AsyncCopy, link, rates);
                let extra_agg = if workers > 1 {
                    // hybrid partitioning: fc layers exchange features, not
                    // params — traffic much smaller than param_bytes.
                    let feature_bytes: usize = profiles
                        .iter()
                        .map(|l| (l.fwd_us as usize) * 512) // ∝ activations
                        .sum();
                    link.transfer_us(feature_bytes * (workers - 1) / workers) * 0.3
                } else {
                    0.0
                };
                base + extra_agg
            }
            SystemPolicy::CaffeTree => {
                let base = iteration_time_us(profiles, CopyMode::SyncCopy, link, rates);
                // Tree reduction without peer-to-peer access: every edge of
                // the reduction tree stages through host memory (down+up),
                // the stages serialize on the single host link, and with >2
                // devices the host path contends hard — the 3-GPU
                // regression of Fig 21 ("the data has to go through the CPU
                // memory which incurs extra overhead when there are more
                // than 2 workers").
                let edges = (w - 1.0).max(1.0);
                let hop = link.transfer_us(param_bytes) * 2.0;
                let contention = if workers > 2 { 3.0 } else { 1.0 };
                base + edges * hop * contention
            }
            SystemPolicy::TorchSync => {
                let base = iteration_time_us(profiles, CopyMode::NoCopy, link, rates);
                if workers <= 1 {
                    base
                } else {
                    base + link.transfer_us(2 * param_bytes) * (w - 1.0) / w
                        + link.transfer_us(param_bytes)
                }
            }
            SystemPolicy::TfSyncPs => {
                let base = iteration_time_us(profiles, CopyMode::SyncCopy, link, rates);
                // PS ingress serializes the w gradient streams
                base + link.transfer_us(param_bytes) * (w - 1.0)
            }
            SystemPolicy::MxnetCpuAllreduce => {
                let base = iteration_time_us(profiles, CopyMode::SyncCopy, link, rates);
                // dependency engine overlaps ~60% of the aggregation
                base + link.transfer_us(param_bytes) * (w - 1.0) / w * 0.4
                    + (crate::coordinator::copyqueue::UpdateRates::default().host_us_per_mb
                        * (param_bytes as f64 / 1e6))
                        * 0.2
            }
        }
    }

    /// Throughput in images/second for per-device batch `batch`.
    pub fn throughput(
        &self,
        profiles: &[LayerProfile],
        workers: usize,
        batch_per_worker: usize,
        link: &LinkModel,
        rates: &UpdateRates,
    ) -> f64 {
        let t_us = self.iteration_us(profiles, workers, link, rates);
        (batch_per_worker * workers) as f64 / (t_us / 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::copyqueue::alexnet_like_profiles;

    #[test]
    fn op_parallel_has_diminishing_returns_and_numa_knee() {
        let m = OpParallelModel::caffe();
        let t1 = m.time_ms(100.0, 1);
        let t4 = m.time_ms(100.0, 4);
        let t8 = m.time_ms(100.0, 8);
        let t16 = m.time_ms(100.0, 16);
        assert!(t4 < t1);
        assert!(t8 < t4);
        // Amdahl floor: never below serial fraction
        assert!(t8 > 100.0 * (1.0 - m.parallel_frac));
        // NUMA knee: 16 threads worse than 8 (paper Fig 18a)
        assert!(t16 > t8, "t16 {t16} vs t8 {t8}");
    }

    #[test]
    fn singa_dist_scales_better_than_op_parallel() {
        let m = OpParallelModel::caffe();
        for threads in [2usize, 4, 8] {
            let blas = m.time_ms(100.0, threads);
            let dist = singa_dist_time_ms(100.0, threads, 0.4);
            assert!(dist < blas, "{threads} workers: dist {dist} vs blas {blas}");
        }
    }

    #[test]
    fn allreduce_scales_central_ps_saturates() {
        let net = LinkModel::ethernet_1g();
        let pb = 4 * 1_000_000; // 1M params
        // SINGA allreduce: monotone improvement through 128 workers
        let mut last = f64::INFINITY;
        for &w in &[4usize, 8, 16, 32, 64, 128] {
            let t = allreduce_cluster_time_ms(2000.0, w, w / 4, pb, &net);
            assert!(t < last, "allreduce not improving at {w}: {t} vs {last}");
            last = t;
        }
        // Petuum-style: slower at 128 than at 64 (the paper's regression)
        let t64 = central_ps_cluster_time_ms(2000.0, 64, pb, &net);
        let t128 = central_ps_cluster_time_ms(2000.0, 128, pb, &net);
        assert!(t128 > t64, "central PS should saturate: {t64} -> {t128}");
    }

    #[test]
    fn singa_fastest_across_worker_counts() {
        let p = alexnet_like_profiles(96);
        let link = LinkModel::pcie3();
        let rates = UpdateRates::default();
        for workers in 1..=3 {
            let singa = SystemPolicy::Singa.throughput(&p, workers, 96, &link, &rates);
            for other in [
                SystemPolicy::CaffeTree,
                SystemPolicy::TfSyncPs,
                SystemPolicy::MxnetCpuAllreduce,
            ] {
                let t = other.throughput(&p, workers, 96, &link, &rates);
                assert!(
                    singa >= t * 0.98,
                    "{} beats SINGA at {workers} workers: {t} vs {singa}",
                    other.name()
                );
            }
        }
    }

    #[test]
    fn caffe_drops_at_three_workers() {
        // Paper Fig 21a: Caffe throughput decreases from 2 to 3 GPUs.
        let p = alexnet_like_profiles(96);
        let link = LinkModel::pcie3();
        let rates = UpdateRates::default();
        let t2 = SystemPolicy::CaffeTree.throughput(&p, 2, 96, &link, &rates);
        let t3 = SystemPolicy::CaffeTree.throughput(&p, 3, 96, &link, &rates);
        assert!(t3 < t2, "caffe 3-gpu {t3} should drop below 2-gpu {t2}");
    }

    #[test]
    fn every_policy_single_device_close_to_compute_bound() {
        // On one device the systems mostly tie (paper: "on a single GPU the
        // difference ... is not significant").
        let p = alexnet_like_profiles(96);
        let link = LinkModel::pcie3();
        let rates = UpdateRates::default();
        let ts: Vec<f64> = SystemPolicy::all()
            .iter()
            .map(|s| s.throughput(&p, 1, 96, &link, &rates))
            .collect();
        let max = ts.iter().cloned().fold(0.0, f64::max);
        let min = ts.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max / min < 1.6, "single-device spread too wide: {ts:?}");
    }
}
