//! Parameter server (paper §5.1): server groups maintain complete replicas
//! of the model parameters; each server in a group manages a partition
//! (shard). Workers send `Update` messages with gradients and fetch fresh
//! values with `Get`.
//!
//! * A [`ServerGroup`] owns a full parameter replica sharded over `size`
//!   servers. Shard assignment is size-balanced (largest params first) so
//!   ingress load spreads evenly.
//! * Inside a worker group, dim-0 replicated sub-layer params are aggregated
//!   by the group's stub before a single `Update` reaches the server (the
//!   paper's stub "aggregates local messages and forwards them").
//! * Across server groups (distributed Hogwild, Fig 11d), neighbouring
//!   groups periodically synchronize by averaging — see [`ServerGroup::sync_with`].

use crate::comm::{ByteLedger, Msg};
use crate::tensor::Blob;
use crate::updater::{Updater, UpdaterConf};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// One parameter's server-side record.
struct ParamEntry {
    value: Blob,
    version: u64,
    lr_mult: f32,
    wd_mult: f32,
}

/// A single server (thread) managing a shard of the parameters.
pub struct ServerShard {
    params: HashMap<String, ParamEntry>,
    updater: Updater,
}

impl ServerShard {
    pub fn new(conf: UpdaterConf) -> ServerShard {
        ServerShard { params: HashMap::new(), updater: Updater::new(conf) }
    }

    /// Handle one message; returns a response for `Get`/`Update`.
    pub fn handle(&mut self, msg: Msg) -> Option<Msg> {
        match msg {
            Msg::Put { param, value, lr_mult, wd_mult } => {
                self.params.insert(
                    param,
                    ParamEntry { value, version: 0, lr_mult, wd_mult },
                );
                None
            }
            Msg::Update { param, grad, step } => {
                let e = self
                    .params
                    .get_mut(&param)
                    .unwrap_or_else(|| panic!("update for unregistered param '{param}'"));
                self.updater.update(&param, &mut e.value, &grad, e.lr_mult, e.wd_mult, step);
                e.version += 1;
                Some(Msg::Response { param, value: e.value.clone(), version: e.version })
            }
            Msg::Get { param } => {
                let e = self
                    .params
                    .get(&param)
                    .unwrap_or_else(|| panic!("get for unregistered param '{param}'"));
                Some(Msg::Response { param, value: e.value.clone(), version: e.version })
            }
            Msg::Response { .. } => None,
        }
    }

    pub fn param_names(&self) -> Vec<String> {
        self.params.keys().cloned().collect()
    }

    pub fn value(&self, name: &str) -> Option<(&Blob, u64)> {
        self.params.get(name).map(|e| (&e.value, e.version))
    }

    /// Overwrite a value (used by inter-group synchronization).
    pub fn set_value(&mut self, name: &str, value: Blob) {
        if let Some(e) = self.params.get_mut(name) {
            e.value = value;
            e.version += 1;
        }
    }
}

/// A server group: `size` shards plus the routing table.
pub struct ServerGroup {
    shards: Vec<Mutex<ServerShard>>,
    /// param name → shard index.
    route: Mutex<HashMap<String, usize>>,
    /// bytes by plane, shared with the workers' ledger.
    pub ledger: Arc<ByteLedger>,
}

impl ServerGroup {
    pub fn new(size: usize, conf: UpdaterConf, ledger: Arc<ByteLedger>) -> ServerGroup {
        assert!(size >= 1);
        ServerGroup {
            shards: (0..size).map(|_| Mutex::new(ServerShard::new(conf.clone()))).collect(),
            route: Mutex::new(HashMap::new()),
            ledger,
        }
    }

    pub fn size(&self) -> usize {
        self.shards.len()
    }

    /// Register a parameter, assigning it to the shard with the least bytes
    /// so far (size-balanced sharding).
    pub fn put(&self, name: &str, value: Blob, lr_mult: f32, wd_mult: f32) {
        let mut route = self.route.lock().unwrap();
        let shard = if let Some(&s) = route.get(name) {
            s
        } else {
            // least-loaded shard by registered parameter bytes
            let mut loads = vec![0usize; self.shards.len()];
            for (p, &s) in route.iter() {
                let _ = p;
                loads[s] += 1;
            }
            // count bytes precisely
            let mut byte_loads = vec![0usize; self.shards.len()];
            for (i, sh) in self.shards.iter().enumerate() {
                let sh = sh.lock().unwrap();
                byte_loads[i] = sh
                    .params
                    .values()
                    .map(|e| e.value.byte_size())
                    .sum();
            }
            let s = byte_loads
                .iter()
                .enumerate()
                .min_by_key(|(_, &b)| b)
                .map(|(i, _)| i)
                .unwrap();
            route.insert(name.to_string(), s);
            s
        };
        drop(route);
        let msg = Msg::Put { param: name.to_string(), value, lr_mult, wd_mult };
        self.ledger.add_param(msg.byte_size());
        self.shards[shard].lock().unwrap().handle(msg);
    }

    fn shard_of(&self, name: &str) -> usize {
        *self
            .route
            .lock()
            .unwrap()
            .get(name)
            .unwrap_or_else(|| panic!("param '{name}' not registered"))
    }

    /// Apply a gradient; returns the fresh value and version.
    pub fn update(&self, name: &str, grad: &Blob, step: u64) -> (Blob, u64) {
        let msg = Msg::Update { param: name.to_string(), grad: grad.clone(), step };
        self.ledger.add_param(msg.byte_size());
        let resp = self.shards[self.shard_of(name)].lock().unwrap().handle(msg).unwrap();
        match resp {
            Msg::Response { value, version, .. } => {
                self.ledger.add_param(value.byte_size() + 64);
                (value, version)
            }
            _ => unreachable!(),
        }
    }

    /// Fetch the current value and version.
    pub fn get(&self, name: &str) -> (Blob, u64) {
        let msg = Msg::Get { param: name.to_string() };
        self.ledger.add_param(msg.byte_size());
        let resp = self.shards[self.shard_of(name)].lock().unwrap().handle(msg).unwrap();
        match resp {
            Msg::Response { value, version, .. } => {
                self.ledger.add_param(value.byte_size() + 64);
                (value, version)
            }
            _ => unreachable!(),
        }
    }

    pub fn param_names(&self) -> Vec<String> {
        self.route.lock().unwrap().keys().cloned().collect()
    }

    /// Pairwise synchronization with a neighbouring server group
    /// (distributed Hogwild, Fig 11d): both groups converge to the mean of
    /// their replicas. Returns bytes exchanged (both directions).
    pub fn sync_with(&self, other: &ServerGroup) -> usize {
        let mut bytes = 0;
        for name in self.param_names() {
            let (a, _) = self.get(&name);
            let (b, _) = other.get(&name);
            let mut mean = a.clone();
            mean.add_assign(&b);
            mean.scale(0.5);
            bytes += 2 * mean.byte_size();
            self.shards[self.shard_of(&name)].lock().unwrap().set_value(&name, mean.clone());
            other.shards[other.shard_of(&name)].lock().unwrap().set_value(&name, mean);
        }
        self.ledger.add_param(bytes);
        bytes
    }

    /// Distribution of parameter bytes across shards (for balance tests and
    /// the Fig 18b server-ingress model).
    pub fn shard_loads(&self) -> Vec<usize> {
        self.shards
            .iter()
            .map(|s| {
                s.lock()
                    .unwrap()
                    .params
                    .values()
                    .map(|e| e.value.byte_size())
                    .sum()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::updater::UpdaterConf;

    fn group(size: usize) -> ServerGroup {
        ServerGroup::new(size, UpdaterConf::sgd(0.1), Arc::new(ByteLedger::new()))
    }

    #[test]
    fn put_get_update_roundtrip() {
        let g = group(2);
        g.put("w", Blob::full(&[4], 1.0), 1.0, 1.0);
        let (v, ver) = g.get("w");
        assert_eq!(v.data(), &[1.0; 4]);
        assert_eq!(ver, 0);
        let (v2, ver2) = g.update("w", &Blob::full(&[4], 1.0), 0);
        assert_eq!(ver2, 1);
        for x in v2.data() {
            assert!((x - 0.9).abs() < 1e-6);
        }
    }

    #[test]
    #[should_panic(expected = "not registered")]
    fn get_unregistered_panics() {
        group(1).get("ghost");
    }

    #[test]
    fn sharding_balances_bytes() {
        let g = group(4);
        // Register params of mixed sizes.
        for i in 0..16 {
            let n = 100 + (i % 5) * 50;
            g.put(&format!("p{i}"), Blob::zeros(&[n]), 1.0, 1.0);
        }
        let loads = g.shard_loads();
        let max = *loads.iter().max().unwrap() as f64;
        let min = *loads.iter().min().unwrap() as f64;
        assert!(max / min.max(1.0) < 2.0, "unbalanced shards: {loads:?}");
    }

    /// Server-side updates with weight decay enabled cost exactly the same
    /// blob allocations as without (the decayed gradient is no longer
    /// materialized — it is folded into the fused updater loops).
    #[test]
    fn decayed_server_update_allocates_no_extra_blobs() {
        let per_update = |conf: UpdaterConf| {
            let g = ServerGroup::new(1, conf, Arc::new(ByteLedger::new()));
            g.put("w", Blob::full(&[64], 1.0), 1.0, 1.0);
            let grad = Blob::full(&[64], 0.1);
            g.update("w", &grad, 0); // warm
            let before = Blob::alloc_count();
            g.update("w", &grad, 1);
            Blob::alloc_count() - before
        };
        let plain = per_update(UpdaterConf::sgd(0.1));
        let decayed = per_update(UpdaterConf::sgd(0.1).with_weight_decay(0.01));
        assert_eq!(
            plain, decayed,
            "decay must not add allocations (plain {plain}, decayed {decayed})"
        );
    }

    #[test]
    fn versions_monotonic() {
        let g = group(1);
        g.put("w", Blob::zeros(&[2]), 1.0, 1.0);
        let mut last = 0;
        for step in 0..5 {
            let (_, v) = g.update("w", &Blob::full(&[2], 0.1), step);
            assert!(v > last);
            last = v;
        }
    }

    #[test]
    fn ledger_sees_traffic() {
        let ledger = Arc::new(ByteLedger::new());
        let g = ServerGroup::new(1, UpdaterConf::sgd(0.1), ledger.clone());
        g.put("w", Blob::zeros(&[100]), 1.0, 1.0);
        let before = ledger.param_bytes();
        g.update("w", &Blob::zeros(&[100]), 0);
        // update sends 400B grad + header and receives 400B value + header
        assert!(ledger.param_bytes() >= before + 800);
    }

    #[test]
    fn hogwild_group_sync_averages() {
        let a = group(1);
        let b = group(1);
        a.put("w", Blob::full(&[2], 0.0), 1.0, 1.0);
        b.put("w", Blob::full(&[2], 2.0), 1.0, 1.0);
        let bytes = a.sync_with(&b);
        assert!(bytes > 0);
        assert_eq!(a.get("w").0.data(), &[1.0, 1.0]);
        assert_eq!(b.get("w").0.data(), &[1.0, 1.0]);
    }
}
