//! Parameter server (paper §5.1): server groups maintain complete replicas
//! of the model parameters; each server in a group manages a partition
//! (shard). Workers send `Update` messages with gradients and fetch fresh
//! values with `Get`.
//!
//! * A [`ServerGroup`] owns a full parameter replica sharded over `size`
//!   servers. Shard assignment is size-balanced (largest params first) so
//!   ingress load spreads evenly.
//! * Inside a worker group, dim-0 replicated sub-layer params are aggregated
//!   by the group's stub before a single `Update` reaches the server (the
//!   paper's stub "aggregates local messages and forwards them").
//! * Across server groups (distributed Hogwild, Fig 11d), neighbouring
//!   groups periodically synchronize by averaging — see [`ServerGroup::sync_with`].

use crate::comm::{ByteLedger, Msg};
use crate::runtime::sync::{OrderedMutex, RANK_SERVER_ROUTE, RANK_SERVER_SHARD};
use crate::tensor::Blob;
use crate::updater::{Updater, UpdaterConf};
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Global creation counter giving every [`ServerGroup`] a unique id — the
/// fixed total order [`ServerGroup::sync_with`] acquires shard locks in.
static GROUP_IDS: AtomicU64 = AtomicU64::new(0);

/// One parameter's server-side record.
struct ParamEntry {
    value: Blob,
    version: u64,
    lr_mult: f32,
    wd_mult: f32,
}

/// A single server (thread) managing a shard of the parameters.
pub struct ServerShard {
    params: HashMap<String, ParamEntry>,
    updater: Updater,
}

impl ServerShard {
    pub fn new(conf: UpdaterConf) -> ServerShard {
        ServerShard { params: HashMap::new(), updater: Updater::new(conf) }
    }

    /// Handle one message; returns a response for `Get`/`Update`. Allocating
    /// wrapper over the `_into` cores below, preserved for tests and any
    /// caller that wants message-owned values.
    pub fn handle(&mut self, msg: Msg) -> Option<Msg> {
        match msg {
            Msg::Put { param, value, lr_mult, wd_mult } => {
                self.params.insert(
                    param,
                    ParamEntry { value, version: 0, lr_mult, wd_mult },
                );
                None
            }
            Msg::Update { param, grad, step } => {
                let mut value = Blob::default();
                let version = self.update_into(&param, &grad, step, &mut value);
                Some(Msg::Response { param, value, version })
            }
            Msg::Get { param } => {
                let mut value = Blob::default();
                let version = self.get_into(&param, &mut value);
                Some(Msg::Response { param, value, version })
            }
            Msg::Response { .. } => None,
        }
    }

    /// Apply `grad` through the fused updater and copy the fresh value into
    /// `out` (resized to fit; allocation-free once sized); returns the new
    /// version. The zero-clone core behind `handle(Msg::Update)`.
    pub fn update_into(&mut self, name: &str, grad: &Blob, step: u64, out: &mut Blob) -> u64 {
        let e = self
            .params
            .get_mut(name)
            .unwrap_or_else(|| panic!("update for unregistered param '{name}'"));
        self.updater.update(name, &mut e.value, grad, e.lr_mult, e.wd_mult, step);
        e.version += 1;
        out.copy_from(&e.value);
        e.version
    }

    /// Copy the current value into `out`; returns the version. The
    /// zero-clone core behind `handle(Msg::Get)`.
    pub fn get_into(&self, name: &str, out: &mut Blob) -> u64 {
        let e = self
            .params
            .get(name)
            .unwrap_or_else(|| panic!("get for unregistered param '{name}'"));
        out.copy_from(&e.value);
        e.version
    }

    pub fn param_names(&self) -> Vec<String> {
        self.params.keys().cloned().collect()
    }

    pub fn value(&self, name: &str) -> Option<(&Blob, u64)> {
        self.params.get(name).map(|e| (&e.value, e.version))
    }
}

/// The routing table: shard assignment per param plus a running byte tally
/// per shard, maintained at registration time so `put` never re-walks every
/// shard's parameter map under the route lock.
#[derive(Default)]
struct RouteTable {
    /// param name → (shard index, registered value bytes).
    by_name: HashMap<String, (usize, usize)>,
    /// Running registered-byte tally per shard.
    shard_bytes: Vec<usize>,
}

/// A server group: `size` shards plus the routing table.
pub struct ServerGroup {
    /// Global creation-order id; `sync_with` locks groups in ascending id
    /// order so concurrent neighbour syncs can never deadlock. The shard
    /// mutexes carry `(id << 16) | shard` as their explicit ordering key, so
    /// the sanitizer verifies that claim on every multi-shard acquisition.
    id: u64,
    shards: Vec<OrderedMutex<ServerShard>>,
    route: OrderedMutex<RouteTable>,
    /// bytes by plane, shared with the workers' ledger.
    pub ledger: Arc<ByteLedger>,
}

impl ServerGroup {
    pub fn new(size: usize, conf: UpdaterConf, ledger: Arc<ByteLedger>) -> ServerGroup {
        assert!(size >= 1);
        let id = GROUP_IDS.fetch_add(1, Ordering::Relaxed);
        ServerGroup {
            id,
            shards: (0..size as u64)
                .map(|s| {
                    OrderedMutex::with_key(
                        RANK_SERVER_SHARD,
                        "server.shard",
                        (id << 16) | s,
                        ServerShard::new(conf.clone()),
                    )
                })
                .collect(),
            route: OrderedMutex::new(
                RANK_SERVER_ROUTE,
                "server.route",
                RouteTable {
                    by_name: HashMap::new(),
                    shard_bytes: vec![0; size], // lint: alloc-ok(group construction, once per job)
                },
            ),
            ledger,
        }
    }

    pub fn size(&self) -> usize {
        self.shards.len()
    }

    /// Register a parameter, assigning it to the shard with the least
    /// registered bytes so far (size-balanced sharding). Re-registering a
    /// name keeps its shard and adjusts the byte tally.
    pub fn put(&self, name: &str, value: Blob, lr_mult: f32, wd_mult: f32) {
        let bytes = value.byte_size();
        let mut route = self.route.lock().unwrap();
        let RouteTable { by_name, shard_bytes } = &mut *route;
        let shard = if let Some(entry) = by_name.get_mut(name) {
            let (s, old) = *entry;
            shard_bytes[s] = shard_bytes[s] - old + bytes;
            entry.1 = bytes;
            s
        } else {
            let s = shard_bytes
                .iter()
                .enumerate()
                .min_by_key(|(_, &b)| b)
                .map(|(i, _)| i)
                .unwrap();
            shard_bytes[s] += bytes;
            by_name.insert(name.to_string(), (s, bytes));
            s
        };
        drop(route);
        self.ledger.add_param(Msg::put_wire_size(name, &value));
        let msg = Msg::Put { param: name.to_string(), value, lr_mult, wd_mult };
        self.shards[shard].lock().unwrap().handle(msg);
    }

    fn shard_of(&self, name: &str) -> usize {
        self.route
            .lock()
            .unwrap()
            .by_name
            .get(name)
            .unwrap_or_else(|| panic!("param '{name}' not registered"))
            .0
    }

    /// Apply a gradient; returns the fresh value and version. Allocating
    /// wrapper over [`ServerGroup::update_into`].
    pub fn update(&self, name: &str, grad: &Blob, step: u64) -> (Blob, u64) {
        let mut value = Blob::default();
        let version = self.update_into(name, grad, step, &mut value);
        (value, version)
    }

    /// Apply a gradient and copy the fresh value into `value_out` — no
    /// message-owned clones on either direction of the round trip; returns
    /// the new version. Byte accounting is identical to the allocating path.
    pub fn update_into(&self, name: &str, grad: &Blob, step: u64, value_out: &mut Blob) -> u64 {
        self.ledger.add_param(Msg::update_wire_size(name, grad));
        let version = self.shards[self.shard_of(name)]
            .lock()
            .unwrap()
            .update_into(name, grad, step, value_out);
        self.ledger.add_param(Msg::response_wire_size(value_out));
        version
    }

    /// [`ServerGroup::update_into`] with caller-supplied wire charges — the
    /// codec path: `grad` is the *decoded* (dequantized) payload the
    /// updater consumes, while the ledger is charged the compressed
    /// request/response bytes that actually crossed the modeled wire.
    pub fn update_into_sized(
        &self,
        name: &str,
        grad: &Blob,
        step: u64,
        value_out: &mut Blob,
        up_bytes: usize,
        down_bytes: usize,
    ) -> u64 {
        self.ledger.add_param(up_bytes);
        let version = self.shards[self.shard_of(name)]
            .lock()
            .unwrap()
            .update_into(name, grad, step, value_out);
        self.ledger.add_param(down_bytes);
        version
    }

    /// Fetch the current value and version. Allocating wrapper over
    /// [`ServerGroup::get_into`].
    pub fn get(&self, name: &str) -> (Blob, u64) {
        let mut value = Blob::default();
        let version = self.get_into(name, &mut value);
        (value, version)
    }

    /// Copy the current value into `value_out`; returns the version.
    pub fn get_into(&self, name: &str, value_out: &mut Blob) -> u64 {
        self.ledger.add_param(Msg::get_wire_size(name));
        let version =
            self.shards[self.shard_of(name)].lock().unwrap().get_into(name, value_out);
        self.ledger.add_param(Msg::response_wire_size(value_out));
        version
    }

    /// [`ServerGroup::get_into`] with a caller-supplied response charge —
    /// the codec path: the value comes back as an encoded chunk, so the
    /// ledger sees its compressed size instead of the full f32 payload.
    pub fn get_into_sized(&self, name: &str, value_out: &mut Blob, down_bytes: usize) -> u64 {
        self.ledger.add_param(Msg::get_wire_size(name));
        let version =
            self.shards[self.shard_of(name)].lock().unwrap().get_into(name, value_out);
        self.ledger.add_param(down_bytes);
        version
    }

    pub fn param_names(&self) -> Vec<String> {
        self.route.lock().unwrap().by_name.keys().cloned().collect()
    }

    /// Pairwise synchronization with a neighbouring server group
    /// (distributed Hogwild, Fig 11d): both groups converge to the mean of
    /// their replicas, averaged in place over the server buffers (no value
    /// clones). Returns bytes exchanged (both directions).
    ///
    /// Every shard of both groups is locked for the whole exchange, in a
    /// fixed global order (ascending group id, then shard index). Concurrent
    /// neighbour syncs — including the reversed `b.sync_with(a)` and chains
    /// like `a↔b` with `b↔c` — therefore serialize instead of deadlocking,
    /// and no worker or neighbour can interleave an update between the read
    /// and the write-back of a half-synced replica (a torn average).
    pub fn sync_with(&self, other: &ServerGroup) -> usize {
        assert!(
            !std::ptr::eq(self, other),
            "sync_with requires two distinct server groups"
        );
        // Resolve routes before taking shard locks (route locks are never
        // held together with shard locks in this module).
        let pairs: Vec<(String, usize, usize)> = self
            .param_names()
            .into_iter()
            .map(|n| {
                let a = self.shard_of(&n);
                let b = other.shard_of(&n);
                (n, a, b)
            })
            .collect();
        let (first, second) = if self.id < other.id { (self, other) } else { (other, self) };
        let mut first_guards: Vec<_> = first.shards.iter().map(|s| s.lock().unwrap()).collect();
        let mut second_guards: Vec<_> = second.shards.iter().map(|s| s.lock().unwrap()).collect();
        let (self_guards, other_guards) = if std::ptr::eq(first, self) {
            (&mut first_guards, &mut second_guards)
        } else {
            (&mut second_guards, &mut first_guards)
        };
        let mut bytes = 0;
        for (name, sa, sb) in &pairs {
            let ea = self_guards[*sa]
                .params
                .get_mut(name)
                .unwrap_or_else(|| panic!("sync_with: param '{name}' missing from own shard"));
            let eb = other_guards[*sb]
                .params
                .get_mut(name)
                .unwrap_or_else(|| panic!("sync_with: param '{name}' missing from neighbour"));
            assert_eq!(
                ea.value.shape(),
                eb.value.shape(),
                "sync_with shape mismatch for {name}"
            );
            // In-place mean, same arithmetic as the historical
            // clone + add_assign + scale(0.5): (a + b) * 0.5 per element.
            for (x, y) in ea.value.data_mut().iter_mut().zip(eb.value.data_mut()) {
                let m = (*x + *y) * 0.5;
                *x = m;
                *y = m;
            }
            ea.version += 1;
            eb.version += 1;
            bytes += 2 * ea.value.byte_size();
        }
        self.ledger.add_param(bytes);
        bytes
    }

    /// Snapshot every registered parameter's current value (name → clone) —
    /// the checkpointer thread's read path. Reads the shards directly (one
    /// lock at a time) instead of going through `get`, so snapshot traffic
    /// never pollutes the worker ledger's param-byte accounting.
    pub fn export_params(&self) -> HashMap<String, Blob> {
        let names = self.param_names();
        let mut out = HashMap::with_capacity(names.len());
        for name in names {
            let shard = self.shard_of(&name);
            let guard = self.shards[shard].lock().unwrap();
            let (v, _) = guard.value(&name).expect("routed param present in shard");
            out.insert(name, v.clone());
        }
        out
    }

    /// Overwrite registered parameters in place from a checkpoint snapshot
    /// (worker-group recovery): values are copied into the existing server
    /// buffers (no Blob allocation) and versions bumped. Params absent from
    /// `tensors` are left untouched; a shape mismatch aborts with an error
    /// naming the param. Returns the number restored.
    pub fn restore_params(&self, tensors: &HashMap<String, Blob>) -> Result<usize> {
        let mut n = 0;
        for name in self.param_names() {
            if let Some(v) = tensors.get(&name) {
                let shard = self.shard_of(&name);
                let mut guard = self.shards[shard].lock().unwrap();
                let e = guard.params.get_mut(&name).expect("routed param present in shard");
                if e.value.shape() != v.shape() {
                    return Err(anyhow!(
                        "checkpoint/server shape mismatch for '{name}': checkpoint {:?} vs server {:?}",
                        v.shape(),
                        e.value.shape()
                    ));
                }
                e.value.copy_from(v);
                e.version += 1;
                n += 1;
            }
        }
        Ok(n)
    }

    /// Registered-byte tally per shard from the route table (the running
    /// counterpart of the [`ServerGroup::shard_loads`] walk).
    pub fn registered_shard_bytes(&self) -> Vec<usize> {
        self.route.lock().unwrap().shard_bytes.clone()
    }

    /// Distribution of parameter bytes across shards (for balance tests and
    /// the Fig 18b server-ingress model).
    pub fn shard_loads(&self) -> Vec<usize> {
        self.shards
            .iter()
            .map(|s| {
                s.lock()
                    .unwrap()
                    .params
                    .values()
                    .map(|e| e.value.byte_size())
                    .sum()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::updater::UpdaterConf;

    fn group(size: usize) -> ServerGroup {
        ServerGroup::new(size, UpdaterConf::sgd(0.1), Arc::new(ByteLedger::new()))
    }

    #[test]
    fn put_get_update_roundtrip() {
        let g = group(2);
        g.put("w", Blob::full(&[4], 1.0), 1.0, 1.0);
        let (v, ver) = g.get("w");
        assert_eq!(v.data(), &[1.0; 4]);
        assert_eq!(ver, 0);
        let (v2, ver2) = g.update("w", &Blob::full(&[4], 1.0), 0);
        assert_eq!(ver2, 1);
        for x in v2.data() {
            assert!((x - 0.9).abs() < 1e-6);
        }
    }

    #[test]
    #[should_panic(expected = "not registered")]
    fn get_unregistered_panics() {
        group(1).get("ghost");
    }

    #[test]
    fn sharding_balances_bytes() {
        let g = group(4);
        // Register params of mixed sizes.
        for i in 0..16 {
            let n = 100 + (i % 5) * 50;
            g.put(&format!("p{i}"), Blob::zeros(&[n]), 1.0, 1.0);
        }
        let loads = g.shard_loads();
        let max = *loads.iter().max().unwrap() as f64;
        let min = *loads.iter().min().unwrap() as f64;
        assert!(max / min.max(1.0) < 2.0, "unbalanced shards: {loads:?}");
    }

    /// Server-side updates with weight decay enabled cost exactly the same
    /// blob allocations as without (the decayed gradient is no longer
    /// materialized — it is folded into the fused updater loops).
    #[test]
    fn decayed_server_update_allocates_no_extra_blobs() {
        let per_update = |conf: UpdaterConf| {
            let g = ServerGroup::new(1, conf, Arc::new(ByteLedger::new()));
            g.put("w", Blob::full(&[64], 1.0), 1.0, 1.0);
            let grad = Blob::full(&[64], 0.1);
            g.update("w", &grad, 0); // warm
            let before = Blob::alloc_count();
            g.update("w", &grad, 1);
            Blob::alloc_count() - before
        };
        let plain = per_update(UpdaterConf::sgd(0.1));
        let decayed = per_update(UpdaterConf::sgd(0.1).with_weight_decay(0.01));
        assert_eq!(
            plain, decayed,
            "decay must not add allocations (plain {plain}, decayed {decayed})"
        );
    }

    #[test]
    fn versions_monotonic() {
        let g = group(1);
        g.put("w", Blob::zeros(&[2]), 1.0, 1.0);
        let mut last = 0;
        for step in 0..5 {
            let (_, v) = g.update("w", &Blob::full(&[2], 0.1), step);
            assert!(v > last);
            last = v;
        }
    }

    #[test]
    fn ledger_sees_traffic() {
        let ledger = Arc::new(ByteLedger::new());
        let g = ServerGroup::new(1, UpdaterConf::sgd(0.1), ledger.clone());
        g.put("w", Blob::zeros(&[100]), 1.0, 1.0);
        let before = ledger.param_bytes();
        g.update("w", &Blob::zeros(&[100]), 0);
        // update sends 400B grad + header and receives 400B value + header
        assert!(ledger.param_bytes() >= before + 800);
    }

    #[test]
    fn hogwild_group_sync_averages() {
        let a = group(1);
        let b = group(1);
        a.put("w", Blob::full(&[2], 0.0), 1.0, 1.0);
        b.put("w", Blob::full(&[2], 2.0), 1.0, 1.0);
        let bytes = a.sync_with(&b);
        assert!(bytes > 0);
        assert_eq!(a.get("w").0.data(), &[1.0, 1.0]);
        assert_eq!(b.get("w").0.data(), &[1.0, 1.0]);
    }

    /// The `_into` fast path must be bit-identical to the allocating
    /// message wrappers: same values, same versions, same ledger bytes.
    #[test]
    fn update_into_matches_allocating_update_bitwise() {
        let mk = || {
            let g = ServerGroup::new(2, UpdaterConf::sgd_momentum(0.1, 0.9), Arc::new(ByteLedger::new()));
            g.put("w", Blob::full(&[16], 1.0), 1.0, 1.0);
            g.put("b", Blob::full(&[4], -0.5), 2.0, 0.0);
            g
        };
        let (a, b) = (mk(), mk());
        let mut out = Blob::default();
        for step in 0..5u64 {
            for name in ["w", "b"] {
                let grad = Blob::full(if name == "w" { &[16] } else { &[4] }, 0.25);
                let (v1, ver1) = a.update(name, &grad, step);
                let ver2 = b.update_into(name, &grad, step, &mut out);
                assert_eq!(ver1, ver2);
                assert_eq!(v1.shape(), out.shape());
                for (x, y) in v1.data().iter().zip(out.data()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{name} step {step}");
                }
            }
        }
        assert_eq!(a.ledger.param_bytes(), b.ledger.param_bytes(), "ledger accounting drifted");
        // get_into agrees with get too.
        let (v, ver) = a.get("w");
        let mut out2 = Blob::default();
        let ver2 = a.get_into("w", &mut out2);
        assert_eq!(ver, ver2);
        assert_eq!(v.data(), out2.data());
    }

    /// After the first call sized the caller's buffer, `update_into` and
    /// `get_into` perform zero Blob allocations per round trip.
    #[test]
    fn into_roundtrips_allocate_nothing_after_warmup() {
        let g = group(2);
        g.put("w", Blob::full(&[64], 1.0), 1.0, 1.0);
        let grad = Blob::full(&[64], 0.1);
        let mut fresh = Blob::default();
        g.update_into("w", &grad, 0, &mut fresh); // sizes the buffer
        g.get_into("w", &mut fresh);
        let before = Blob::alloc_count();
        for step in 1..6 {
            g.update_into("w", &grad, step, &mut fresh);
            g.get_into("w", &mut fresh);
        }
        assert_eq!(Blob::alloc_count(), before, "steady-state round trips must not allocate");
    }

    /// The running route-table byte tally must match the ground-truth shard
    /// walk, including after a re-registration that changes a value's size.
    #[test]
    fn registered_shard_bytes_tracks_actual_loads() {
        let g = group(3);
        for i in 0..10 {
            g.put(&format!("p{i}"), Blob::zeros(&[50 + i * 30]), 1.0, 1.0);
        }
        assert_eq!(g.registered_shard_bytes(), g.shard_loads());
        // Re-register p3 with a different size: same shard, adjusted tally.
        g.put("p3", Blob::zeros(&[500]), 1.0, 1.0);
        assert_eq!(g.registered_shard_bytes(), g.shard_loads());
        assert_eq!(
            g.registered_shard_bytes().iter().sum::<usize>(),
            (0..10).map(|i| if i == 3 { 500 * 4 } else { (50 + i * 30) * 4 }).sum::<usize>()
        );
    }

    /// Snapshot/restore round trip across a sharded group: values survive,
    /// versions bump, the ledger never sees checkpoint traffic, and the
    /// restore copies into existing server buffers without allocating.
    #[test]
    fn export_restore_roundtrip_bypasses_ledger() {
        let ledger = Arc::new(ByteLedger::new());
        let g = ServerGroup::new(3, UpdaterConf::sgd(0.1), ledger.clone());
        for i in 0..5 {
            g.put(&format!("p{i}"), Blob::full(&[8 + i], i as f32), 1.0, 1.0);
        }
        let before_bytes = ledger.param_bytes();
        let snap = g.export_params();
        assert_eq!(snap.len(), 5);
        // Perturb, then restore the snapshot.
        for i in 0..5 {
            g.update(&format!("p{i}"), &Blob::full(&[8 + i], 1.0), 0);
        }
        let after_updates = ledger.param_bytes();
        let before_allocs = Blob::alloc_count();
        assert_eq!(g.restore_params(&snap).unwrap(), 5);
        assert_eq!(Blob::alloc_count(), before_allocs, "restore must copy in place");
        assert_eq!(ledger.param_bytes(), after_updates, "snapshot/restore must not hit the ledger");
        assert!(after_updates > before_bytes, "real updates do hit the ledger");
        for i in 0..5 {
            let (v, ver) = g.get(&format!("p{i}"));
            assert!(v.data().iter().all(|&x| x == i as f32), "p{i} not restored");
            assert!(ver >= 2, "restore must bump the version");
        }
    }

    /// A snapshot with a mismatched shape is an error naming the param;
    /// missing params are skipped, not errors.
    #[test]
    fn restore_params_shape_mismatch_errors() {
        let g = group(2);
        g.put("w", Blob::zeros(&[4]), 1.0, 1.0);
        g.put("b", Blob::zeros(&[2]), 1.0, 1.0);
        let mut snap = HashMap::new();
        snap.insert("w".to_string(), Blob::zeros(&[5]));
        let err = g.restore_params(&snap).unwrap_err();
        assert!(err.to_string().contains("'w'"), "{err}");
        snap.insert("w".to_string(), Blob::full(&[4], 9.0));
        assert_eq!(g.restore_params(&snap).unwrap(), 1); // "b" untouched, skipped
        assert_eq!(g.get("w").0.data(), &[9.0; 4]);
    }

    /// Concurrent opposing neighbour syncs must neither deadlock nor tear:
    /// with replicas at constant 0 and constant 2, every serialization of
    /// whole-group syncs yields exactly 1.0 everywhere. The historical
    /// per-name get/set interleaving could average a half-synced replica
    /// (e.g. reading 1 and 2 → 1.5) or deadlock under a lock-per-side
    /// scheme; the fixed global lock order forbids both.
    #[test]
    fn concurrent_neighbour_syncs_do_not_deadlock_or_tear() {
        let a = Arc::new(group(2));
        let b = Arc::new(group(2));
        for i in 0..6 {
            a.put(&format!("p{i}"), Blob::full(&[128], 0.0), 1.0, 1.0);
            b.put(&format!("p{i}"), Blob::full(&[128], 2.0), 1.0, 1.0);
        }
        let t1 = {
            let (a, b) = (a.clone(), b.clone());
            std::thread::spawn(move || {
                for _ in 0..50 {
                    a.sync_with(&b);
                }
            })
        };
        let t2 = {
            let (a, b) = (a.clone(), b.clone());
            std::thread::spawn(move || {
                for _ in 0..50 {
                    b.sync_with(&a);
                }
            })
        };
        t1.join().unwrap();
        t2.join().unwrap();
        for i in 0..6 {
            for g in [&a, &b] {
                let (v, _) = g.get(&format!("p{i}"));
                assert!(
                    v.data().iter().all(|&x| x == 1.0),
                    "torn average detected in p{i}"
                );
            }
        }
    }
}
