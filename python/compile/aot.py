"""AOT lowering: jit the L2 step functions, lower to HLO *text* (NOT
serialized proto — jax>=0.5 emits 64-bit instruction ids that the image's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly, see /opt/xla-example/README.md), and write a ``manifest.json`` the
rust runtime uses to wire inputs/outputs.

Usage: ``python -m compile.aot --out ../artifacts`` (see the Makefile).
"""

import argparse
import hashlib
import json
import os

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(arr):
    a = np.asarray(arr)
    return jax.ShapeDtypeStruct(a.shape, a.dtype)


def catalogue():
    """Every artifact: name -> (fn, example inputs, input names, output names).

    Input names mark parameters with a ``param:`` prefix so the rust worker
    knows which inputs come from the parameter server.
    """
    cat = {}

    # MLP
    params = model.init_mlp()
    x = np.zeros((model.MLP_BATCH, model.MLP_DIMS[0]), np.float32)
    y = np.zeros((model.MLP_BATCH, model.MLP_DIMS[-1]), np.float32)
    pnames = []
    for i in range(len(params) // 2):
        pnames += [f"param:mlp/w{i}", f"param:mlp/b{i}"]
    cat["mlp_step"] = (
        model.mlp_step,
        [*params, x, y],
        [*pnames, "data", "label_onehot"],
        ["loss", "logits"] + [n.replace("param:", "grad:") for n in pnames],
    )

    # CNN
    cparams = model.init_cnn()
    cx = np.zeros((model.CNN_BATCH, *model.CNN_SHAPE), np.float32)
    cy = np.zeros((model.CNN_BATCH, model.CNN_CLASSES), np.float32)
    cnames = [
        "param:cnn/conv1_w", "param:cnn/conv1_b",
        "param:cnn/conv2_w", "param:cnn/conv2_b",
        "param:cnn/fc_w", "param:cnn/fc_b",
    ]
    cat["cnn_step"] = (
        model.cnn_step,
        [*cparams, cx, cy],
        [*cnames, "data", "label_onehot"],
        ["loss", "logits"] + [n.replace("param:", "grad:") for n in cnames],
    )

    # Char-RNN
    rparams = model.init_charrnn()
    ids = np.zeros((model.RNN_BATCH, model.RNN_STEPS), np.int32)
    labels = np.zeros(
        (model.RNN_BATCH, model.RNN_STEPS, model.RNN_VOCAB), np.float32
    )
    rnames = [
        "param:rnn/w", "param:rnn/u", "param:rnn/b",
        "param:rnn/proj_w", "param:rnn/proj_b",
    ]
    cat["charrnn_step"] = (
        model.charrnn_step,
        [*rparams, ids, labels],
        [*rnames, "chars", "labels_onehot"],
        ["loss", "logits"] + [n.replace("param:", "grad:") for n in rnames],
    )

    return cat


def source_fingerprint():
    """Hash of the compile-path sources for incremental `make artifacts`."""
    here = os.path.dirname(__file__)
    h = hashlib.sha256()
    for root, _, files in sorted(os.walk(here)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated artifact names")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    fp = source_fingerprint()
    stamp = os.path.join(args.out, "fingerprint.txt")
    if os.path.exists(stamp) and open(stamp).read().strip() == fp and not args.only:
        print("artifacts up to date")
        return

    manifest = {"artifacts": {}}
    only = set(args.only.split(",")) if args.only else None
    for name, (fn, examples, in_names, out_names) in catalogue().items():
        if only and name not in only:
            continue
        specs = [_spec(a) for a in examples]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *specs)
        manifest["artifacts"][name] = {
            "file": fname,
            "inputs": [
                {"name": n, "shape": list(s.shape), "dtype": str(s.dtype)}
                for n, s in zip(in_names, specs)
            ],
            "outputs": [
                {"name": n, "shape": list(np.shape(o)), "dtype": str(o.dtype)}
                for n, o in zip(out_names, outs)
            ],
        }
        print(f"lowered {name}: {len(text)} chars, {len(specs)} inputs")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    with open(stamp, "w") as f:
        f.write(fp)
    print(f"wrote manifest with {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
