"""L2: JAX model step functions (forward + backward + loss), built on the
L1 Pallas kernels, AOT-lowered once by ``aot.py`` into HLO-text artifacts
the rust runtime executes. Python never runs on the training path.

Each ``*_step`` takes ``(params..., batch inputs...)`` and returns
``(loss, logits, *grads)`` with grads in the same order as params, so the
rust worker can ship them straight to the parameter server.
"""

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import linear as lin
from .kernels import matmul as mm
from .kernels import ref


# --------------------------- MLP ---------------------------

MLP_DIMS = (784, 256, 10)
MLP_BATCH = 32


def init_mlp(seed=0, dims=MLP_DIMS):
    rng = np.random.RandomState(seed)
    params = []
    for i in range(len(dims) - 1):
        params.append(
            (0.05 * rng.randn(dims[i], dims[i + 1])).astype(np.float32)
        )
        params.append(np.zeros(dims[i + 1], dtype=np.float32))
    return params


def mlp_logits(params, x):
    h = x
    n_layers = len(params) // 2
    for i in range(n_layers):
        w, b = params[2 * i], params[2 * i + 1]
        act = "relu" if i + 1 < n_layers else "identity"
        h = lin.linear(h, w, b, act)
    return h


def mlp_loss(params, x, y1hot):
    logits = mlp_logits(params, x)
    loss, _ = ref.softmax_xent(logits, y1hot)
    return loss, logits


def mlp_step(*args):
    """(w1,b1,...,x,y1hot) -> (loss, logits, dw1,db1,...)."""
    *params, x, y = args
    (loss, logits), grads = jax.value_and_grad(mlp_loss, has_aux=True)(
        list(params), x, y
    )
    return (loss, logits, *grads)


# --------------------------- CNN (CIFAR convnet) ---------------------------

CNN_BATCH = 8
CNN_SHAPE = (3, 32, 32)
CNN_CLASSES = 10


def init_cnn(seed=0):
    rng = np.random.RandomState(seed)
    p = []
    # conv1: 16 filters 5x5 over 3 ch
    p.append((0.1 * rng.randn(16, 3 * 5 * 5)).astype(np.float32))
    p.append(np.zeros(16, dtype=np.float32))
    # conv2: 32 filters 5x5 over 16 ch
    p.append((0.1 * rng.randn(32, 16 * 5 * 5)).astype(np.float32))
    p.append(np.zeros(32, dtype=np.float32))
    # fc: 32*8*8 -> 10
    p.append((0.05 * rng.randn(32 * 8 * 8, CNN_CLASSES)).astype(np.float32))
    p.append(np.zeros(CNN_CLASSES, dtype=np.float32))
    return p


def conv2d(x, w, b, kernel=5, pad=2):
    """NCHW conv via extracted patches + the Pallas GEMM (im2col form —
    the decomposition the paper adopts from Caffe)."""
    bsz, c, h, wd = x.shape
    out_c = w.shape[0]
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(kernel, kernel),
        window_strides=(1, 1),
        padding=((pad, pad), (pad, pad)),
    )  # [B, C*k*k, OH, OW]
    oh, ow = patches.shape[2], patches.shape[3]
    cols = patches.reshape(bsz, c * kernel * kernel, oh * ow)
    # one big GEMM: [B*OHOW, Ckk] @ [Ckk, out_c]
    flat = cols.transpose(0, 2, 1).reshape(bsz * oh * ow, c * kernel * kernel)
    y = mm.matmul(flat, w.T) + b
    return y.reshape(bsz, oh, ow, out_c).transpose(0, 3, 1, 2)


def maxpool2(x):
    b, c, h, w = x.shape
    return jnp.max(x.reshape(b, c, h // 2, 2, w // 2, 2), axis=(3, 5))


def cnn_logits(params, x):
    w1, b1, w2, b2, w3, b3 = params
    h = jnp.maximum(conv2d(x, w1, b1), 0.0)
    h = maxpool2(h)  # 16x16
    h = jnp.maximum(conv2d(h, w2, b2), 0.0)
    h = maxpool2(h)  # 8x8
    h = h.reshape(x.shape[0], -1)
    return lin.linear(h, w3, b3, "identity")


def cnn_loss(params, x, y1hot):
    logits = cnn_logits(params, x)
    loss, _ = ref.softmax_xent(logits, y1hot)
    return loss, logits


def cnn_step(*args):
    *params, x, y = args
    (loss, logits), grads = jax.value_and_grad(cnn_loss, has_aux=True)(
        list(params), x, y
    )
    return (loss, logits, *grads)


# --------------------------- Char-RNN (GRU) ---------------------------

RNN_BATCH = 16
RNN_STEPS = 20
RNN_VOCAB = 64
RNN_HIDDEN = 64


def init_charrnn(seed=0, vocab=RNN_VOCAB, hidden=RNN_HIDDEN):
    rng = np.random.RandomState(seed)
    return [
        (0.08 * rng.randn(vocab, 3 * hidden)).astype(np.float32),  # W
        (0.08 * rng.randn(hidden, 3 * hidden)).astype(np.float32),  # U
        np.zeros(3 * hidden, dtype=np.float32),  # b
        (0.08 * rng.randn(hidden, vocab)).astype(np.float32),  # proj W
        np.zeros(vocab, dtype=np.float32),  # proj b
    ]


def charrnn_logits(params, ids):
    """ids [B, T] int32 -> logits [B, T, V]."""
    w, u, b, pw, pb = params
    hidden = u.shape[0]
    vocab = w.shape[0]
    x1h = jax.nn.one_hot(ids, vocab, dtype=jnp.float32)  # [B,T,V]

    def step(h, x_t):
        xw = lin.linear(x_t, w, b, "identity")  # [B, 3h]
        hu = mm.matmul(h, u)  # [B, 3h]
        r = ref.sigmoid(xw[:, :hidden] + hu[:, :hidden])
        z = ref.sigmoid(xw[:, hidden : 2 * hidden] + hu[:, hidden : 2 * hidden])
        c = jnp.tanh(
            xw[:, 2 * hidden :] + mm.matmul(r * h, u[:, 2 * hidden :])
        )
        h_new = z * h + (1.0 - z) * c
        return h_new, h_new

    bsz = ids.shape[0]
    h0 = jnp.zeros((bsz, hidden), dtype=jnp.float32)
    _, hs = jax.lax.scan(step, h0, x1h.transpose(1, 0, 2))  # [T,B,h]
    logits = lin.linear(
        hs.reshape(-1, hidden), pw, pb, "identity"
    ).reshape(ids.shape[1], bsz, vocab)
    return logits.transpose(1, 0, 2)


def charrnn_loss(params, ids, labels1h):
    """labels1h [B, T, V] one-hot next-char targets."""
    logits = charrnn_logits(params, ids)
    b, t, v = logits.shape
    loss, _ = ref.softmax_xent(logits.reshape(b * t, v), labels1h.reshape(b * t, v))
    return loss, logits


def charrnn_step(*args):
    *params, ids, labels = args
    (loss, logits), grads = jax.value_and_grad(charrnn_loss, has_aux=True)(
        list(params), ids, labels
    )
    return (loss, logits, *grads)
