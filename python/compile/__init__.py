"""Build-time compile path (L2 models + L1 Pallas kernels + AOT lowering).

Never imported at runtime: `make artifacts` runs `python -m compile.aot`
once, and the rust binary is self-contained afterwards.
"""
