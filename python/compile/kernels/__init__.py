"""L1 Pallas kernels (build-time only; lowered into the L2 HLO artifacts).

`matmul` is the workhorse tiled GEMM; `linear` fuses bias+activation into
its epilogue. Both carry custom VJPs so the L2 models are end-to-end
differentiable while every FLOP-heavy op stays inside a Pallas kernel.
`ref` is the pure-jnp oracle used by the pytest/hypothesis suite.
"""
