"""Pallas tiled GEMM — the L1 compute hot-spot.

The paper's hot loops (inner-product layers, im2col convolution, GRU cell
projections) are all GEMMs, so one well-tiled matmul kernel carries the
whole stack. TPU-shaped rather than CUDA-ported (DESIGN.md
§Hardware-Adaptation): blocks default to MXU-friendly 128x128 tiles held in
VMEM, with the K-loop expressed through the grid so pipelining overlaps the
HBM->VMEM streams with MXU compute. `interpret=True` everywhere — the CPU
PJRT plugin cannot execute Mosaic custom-calls; real-TPU efficiency is
estimated in EXPERIMENTS.md §Perf from the VMEM footprint and MXU
utilization of these BlockSpecs.

The kernel is wrapped in `jax.custom_vjp` so L2 models differentiate
through it; both VJP operands are themselves computed by the same kernel
(dx = dy @ w^T, dw = x^T @ dy).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-shaped default tiles; shrunk automatically for small operands.
BM, BN, BK = 128, 128, 128


def _matmul_kernel(x_ref, y_ref, o_ref, *, n_k):
    """Grid = (M/bm, N/bn, K/bk); K is the innermost (minor) grid dim, and
    the output block index does not depend on k, so the o_ref window stays
    resident in VMEM across the K steps and serves as the accumulator."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )
    del n_k


def _ceil_div(a, b):
    return (a + b - 1) // b


def _pad_to(x, m, n):
    pm = _ceil_div(x.shape[0], m) * m - x.shape[0]
    pn = _ceil_div(x.shape[1], n) * n - x.shape[1]
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


def _shrink(block, dim):
    """Clamp a block edge to the (next pow2 of the) actual dim."""
    if dim == 0:
        return block
    p = 1 << (dim - 1).bit_length()
    return max(8, min(block, p))


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul_raw(x, y, bm=BM, bn=BN, bk=BK):
    """`x [m,k] @ y [k,n]` via the Pallas kernel (no VJP)."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"matmul inner dim {k} vs {k2}"
    bm, bn, bk = _shrink(bm, m), _shrink(bn, n), _shrink(bk, k)
    xp = _pad_to(x.astype(jnp.float32), bm, bk)
    yp = _pad_to(y.astype(jnp.float32), bk, bn)
    mp, kp = xp.shape
    _, np_ = yp.shape
    n_k = kp // bk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=(mp // bm, np_ // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, yp)
    return out[:m, :n]


@jax.custom_vjp
def matmul(x, y):
    """Differentiable Pallas GEMM."""
    return matmul_raw(x, y)


def _matmul_fwd(x, y):
    return matmul_raw(x, y), (x, y)


def _matmul_bwd(res, g):
    x, y = res
    dx = matmul_raw(g, y.T)
    dy = matmul_raw(x.T, g)
    return dx, dy


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def vmem_footprint_bytes(bm=BM, bn=BN, bk=BK):
    """Estimated VMEM working set of one grid step: x-tile + y-tile + the
    resident output/accumulator tile, f32, double-buffered inputs (Pallas
    pipelines the next tiles while computing). Used by §Perf."""
    return 4 * (2 * bm * bk + 2 * bk * bn + bm * bn)


def mxu_utilization_estimate(m, k, n, bm=BM, bn=BN, bk=BK):
    """Fraction of MXU issue slots doing useful work: real FLOPs over FLOPs
    including tile-padding waste."""
    bm, bn, bk = _shrink(bm, m), _shrink(bn, n), _shrink(bk, k)
    mp = _ceil_div(m, bm) * bm
    kp = _ceil_div(k, bk) * bk
    np_ = _ceil_div(n, bn) * bn
    return (m * k * n) / float(mp * kp * np_)
