"""Fused affine + activation Pallas kernel: ``act(x @ w + b)``.

This is the inner-product layer of the paper's running example (Fig 4c:
"rotate (multiply W), shift (plus b), apply non-linear transformation") as
one kernel — bias add and activation are fused into the GEMM epilogue so
the pre-activation never round-trips through HBM.

Differentiable via custom_vjp; the backward pass reuses the Pallas GEMM
kernel for both operand gradients and computes the activation chain rule
from the saved output.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import matmul as mm

_ACTS = ("identity", "sigmoid", "tanh", "relu")


def _linear_kernel(x_ref, w_ref, b_ref, o_ref, *, n_k, act):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        y = o_ref[...] + b_ref[...]
        if act == "sigmoid":
            y = 1.0 / (1.0 + jnp.exp(-y))
        elif act == "tanh":
            y = jnp.tanh(y)
        elif act == "relu":
            y = jnp.maximum(y, 0.0)
        o_ref[...] = y


@functools.partial(jax.jit, static_argnames=("act", "bm", "bn", "bk"))
def linear_raw(x, w, b, act="identity", bm=mm.BM, bn=mm.BN, bk=mm.BK):
    assert act in _ACTS, f"unknown activation {act!r}"
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,)
    bm, bn, bk = mm._shrink(bm, m), mm._shrink(bn, n), mm._shrink(bk, k)
    xp = mm._pad_to(x.astype(jnp.float32), bm, bk)
    wp = mm._pad_to(w.astype(jnp.float32), bk, bn)
    bp = jnp.pad(b.astype(jnp.float32), (0, wp.shape[1] - n))[None, :]
    mp, kp = xp.shape
    np_ = wp.shape[1]
    n_k = kp // bk
    out = pl.pallas_call(
        functools.partial(_linear_kernel, n_k=n_k, act=act),
        grid=(mp // bm, np_ // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def linear(x, w, b, act="identity"):
    """Differentiable fused affine+activation."""
    return linear_raw(x, w, b, act)


def _linear_fwd(x, w, b, act):
    y = linear_raw(x, w, b, act)
    return y, (x, w, y)


def _linear_bwd(act, res, g):
    x, w, y = res
    if act == "identity":
        dz = g
    elif act == "sigmoid":
        dz = g * y * (1.0 - y)
    elif act == "tanh":
        dz = g * (1.0 - y * y)
    elif act == "relu":
        dz = jnp.where(y > 0.0, g, 0.0)
    else:  # pragma: no cover
        raise ValueError(act)
    dx = mm.matmul_raw(dz, w.T)
    dw = mm.matmul_raw(x.T, dz)
    db = jnp.sum(dz, axis=0)
    return dx, dw, db


linear.defvjp(_linear_fwd, _linear_bwd)
