"""Pure-jnp oracle for the Pallas kernels (L1 correctness reference).

Every Pallas kernel in this package is checked against these functions by
``python/tests/test_kernels.py`` (hypothesis sweeps shapes); the kernels are
only trusted inside the L2 models once these tests pass.
"""

import jax.numpy as jnp


def matmul(a, b):
    """Plain f32 GEMM."""
    return jnp.matmul(a, b)


def linear(x, w, b, act="identity"):
    """Fused affine + activation: act(x @ w + b)."""
    y = jnp.matmul(x, w) + b
    return apply_act(y, act)


def apply_act(y, act):
    if act == "identity":
        return y
    if act == "sigmoid":
        return 1.0 / (1.0 + jnp.exp(-y))
    if act == "tanh":
        return jnp.tanh(y)
    if act == "relu":
        return jnp.maximum(y, 0.0)
    raise ValueError(f"unknown activation {act!r}")


def softmax_xent(logits, y_onehot):
    """Mean cross-entropy of row softmax vs one-hot labels; also gradient."""
    m = jnp.max(logits, axis=1, keepdims=True)
    e = jnp.exp(logits - m)
    p = e / jnp.sum(e, axis=1, keepdims=True)
    n = logits.shape[0]
    loss = -jnp.mean(jnp.sum(y_onehot * jnp.log(jnp.clip(p, 1e-12)), axis=1))
    grad = (p - y_onehot) / n
    return loss, grad


def gru_cell(xw, hu_rz, h_prev, u_c, b):
    """One GRU step from pre-projected inputs.

    xw     [batch, 3h] : x @ W + b (gates r|z|c, input part)
    hu_rz  [batch, 2h] : h_prev @ U[:, :2h] (recurrent part of r and z)
    h_prev [batch, h]
    u_c    [h, h]      : recurrent weights of the candidate
    b is already folded into xw.
    """
    h = h_prev.shape[1]
    r = sigmoid(xw[:, :h] + hu_rz[:, :h])
    z = sigmoid(xw[:, h : 2 * h] + hu_rz[:, h : 2 * h])
    c = jnp.tanh(xw[:, 2 * h :] + (r * h_prev) @ u_c)
    return z * h_prev + (1.0 - z) * c


def sigmoid(x):
    return 1.0 / (1.0 + jnp.exp(-x))
