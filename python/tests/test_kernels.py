"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes; every kernel must match the oracle within f32
tolerance, including through `jax.grad` (the custom VJPs).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import linear as lin
from compile.kernels import matmul as mm
from compile.kernels import ref

DIM = st.integers(min_value=1, max_value=96)


def rand(rng, *shape):
    return rng.standard_normal(shape, dtype=np.float32)


@settings(max_examples=25, deadline=None)
@given(m=DIM, k=DIM, n=DIM, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, y = rand(rng, m, k), rand(rng, k, n)
    out = np.asarray(mm.matmul(jnp.array(x), jnp.array(y)))
    np.testing.assert_allclose(out, ref.matmul(x, y), rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(1, 48),
    k=st.integers(1, 48),
    n=st.integers(1, 48),
    act=st.sampled_from(["identity", "sigmoid", "tanh", "relu"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_linear_matches_ref(m, k, n, act, seed):
    rng = np.random.default_rng(seed)
    x, w, b = rand(rng, m, k), rand(rng, k, n), rand(rng, n)
    out = np.asarray(lin.linear(jnp.array(x), jnp.array(w), jnp.array(b), act))
    np.testing.assert_allclose(
        out, ref.linear(x, w, b, act), rtol=3e-4, atol=3e-4
    )


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (32, 16, 64), (128, 128, 128)])
def test_matmul_block_shapes_agree(bm, bn, bk):
    rng = np.random.default_rng(7)
    x, y = rand(rng, 50, 70), rand(rng, 70, 30)
    out = np.asarray(mm.matmul_raw(jnp.array(x), jnp.array(y), bm=bm, bn=bn, bk=bk))
    np.testing.assert_allclose(out, x @ y, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("act", ["identity", "sigmoid", "tanh", "relu"])
def test_linear_gradients_match_oracle(act):
    rng = np.random.default_rng(3)
    x, w, b = rand(rng, 9, 13), rand(rng, 13, 7), rand(rng, 7)

    def f_pallas(x, w, b):
        return jnp.sum(lin.linear(x, w, b, act) ** 2)

    def f_ref(x, w, b):
        return jnp.sum(ref.linear(x, w, b, act) ** 2)

    gp = jax.grad(f_pallas, argnums=(0, 1, 2))(jnp.array(x), jnp.array(w), jnp.array(b))
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(jnp.array(x), jnp.array(w), jnp.array(b))
    for a, c in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=2e-3, atol=2e-4)


def test_matmul_gradients_match_oracle():
    rng = np.random.default_rng(5)
    x, y = rand(rng, 6, 11), rand(rng, 11, 4)
    g = rand(rng, 6, 4)

    def f(x, y):
        return jnp.sum(mm.matmul(x, y) * g)

    dx, dy = jax.grad(f, argnums=(0, 1))(jnp.array(x), jnp.array(y))
    np.testing.assert_allclose(np.asarray(dx), g @ y.T, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(dy), x.T @ g, rtol=2e-4, atol=2e-4)


def test_gru_cell_ref_consistency():
    """ref.gru_cell agrees with an independent step-by-step computation."""
    rng = np.random.default_rng(9)
    b, h = 4, 6
    xw = rand(rng, b, 3 * h)
    hu = rand(rng, b, 2 * h)
    hp = rand(rng, b, h)
    uc = rand(rng, h, h)
    out = np.asarray(ref.gru_cell(xw, hu, hp, uc, None))
    r = 1 / (1 + np.exp(-(xw[:, :h] + hu[:, :h])))
    z = 1 / (1 + np.exp(-(xw[:, h : 2 * h] + hu[:, h : 2 * h])))
    c = np.tanh(xw[:, 2 * h :] + (r * hp) @ uc)
    expect = z * hp + (1 - z) * c
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-5)


def test_softmax_xent_ref_grad_numeric():
    rng = np.random.default_rng(2)
    logits = rand(rng, 3, 5)
    y = np.eye(5, dtype=np.float32)[[0, 2, 4]]
    _, grad = ref.softmax_xent(jnp.array(logits), jnp.array(y))
    grad = np.asarray(grad)
    eps = 1e-3
    for i in range(logits.size):
        p = logits.copy().reshape(-1)
        p[i] += eps
        m = logits.copy().reshape(-1)
        m[i] -= eps
        lp, _ = ref.softmax_xent(jnp.array(p.reshape(3, 5)), jnp.array(y))
        lm, _ = ref.softmax_xent(jnp.array(m.reshape(3, 5)), jnp.array(y))
        num = (float(lp) - float(lm)) / (2 * eps)
        assert abs(num - grad.reshape(-1)[i]) < 1e-3


def test_vmem_footprint_within_budget():
    """Default tiles must fit TPU VMEM (16 MiB/core) with headroom."""
    assert mm.vmem_footprint_bytes() < 8 * 1024 * 1024


def test_mxu_utilization_estimates():
    # aligned shapes → perfect utilization
    assert mm.mxu_utilization_estimate(256, 256, 256) == 1.0
    # pathological shape wastes most of the tile
    assert mm.mxu_utilization_estimate(129, 128, 128) < 0.6
