"""L2 correctness: the exported step functions — shapes, gradient sanity,
and a few steps of SGD actually reducing the loss (so the artifacts the
rust runtime executes are known-good before lowering).
"""

import jax
import numpy as np
import pytest

from compile import model


def onehot(ids, n):
    return np.eye(n, dtype=np.float32)[ids]


class TestMlp:
    def setup_method(self):
        self.params = model.init_mlp(seed=1)
        rng = np.random.default_rng(0)
        self.x = rng.standard_normal(
            (model.MLP_BATCH, model.MLP_DIMS[0]), dtype=np.float32
        )
        self.y = onehot(rng.integers(0, 10, model.MLP_BATCH), 10)

    def test_step_shapes(self):
        out = model.mlp_step(*self.params, self.x, self.y)
        loss, logits, *grads = out
        assert loss.shape == ()
        assert logits.shape == (model.MLP_BATCH, 10)
        assert len(grads) == len(self.params)
        for g, p in zip(grads, self.params):
            assert g.shape == p.shape

    def test_sgd_reduces_loss(self):
        params = [p.copy() for p in self.params]
        losses = []
        for _ in range(15):
            loss, _, *grads = model.mlp_step(*params, self.x, self.y)
            losses.append(float(loss))
            params = [p - 0.5 * np.asarray(g) for p, g in zip(params, grads)]
        assert losses[-1] < 0.5 * losses[0], losses

    def test_grads_match_numeric(self):
        loss0, _, *grads = model.mlp_step(*self.params, self.x, self.y)
        # probe a few coordinates of w2
        w_idx = len(self.params) - 2
        g = np.asarray(grads[w_idx])
        eps = 1e-2
        flat_probe = [0, 11, 101]
        for i in flat_probe:
            p = [q.copy() for q in self.params]
            p[w_idx].reshape(-1)[i] += eps
            lp, *_ = model.mlp_step(*p, self.x, self.y)
            p[w_idx].reshape(-1)[i] -= 2 * eps
            lm, *_ = model.mlp_step(*p, self.x, self.y)
            num = (float(lp) - float(lm)) / (2 * eps)
            assert abs(num - g.reshape(-1)[i]) < 5e-3


class TestCnn:
    def setup_method(self):
        self.params = model.init_cnn(seed=2)
        rng = np.random.default_rng(1)
        self.x = rng.standard_normal(
            (model.CNN_BATCH, *model.CNN_SHAPE), dtype=np.float32
        )
        self.y = onehot(rng.integers(0, 10, model.CNN_BATCH), 10)

    def test_logits_shape(self):
        logits = model.cnn_logits(self.params, self.x)
        assert logits.shape == (model.CNN_BATCH, model.CNN_CLASSES)

    def test_step_shapes_and_descent(self):
        loss0, logits, *grads = model.cnn_step(*self.params, self.x, self.y)
        assert len(grads) == 6
        params = [p.copy() for p in self.params]
        losses = []
        for _ in range(6):
            loss, _, *grads = model.cnn_step(*params, self.x, self.y)
            losses.append(float(loss))
            params = [p - 0.02 * np.asarray(g) for p, g in zip(params, grads)]
        assert losses[-1] < 0.5 * losses[0], losses

    def test_conv2d_matches_lax_conv(self):
        rng = np.random.default_rng(3)
        x = rng.standard_normal((2, 3, 8, 8), dtype=np.float32)
        w = rng.standard_normal((4, 3 * 5 * 5), dtype=np.float32)
        b = rng.standard_normal(4, dtype=np.float32)
        out = np.asarray(model.conv2d(x, w, b))
        wk = w.reshape(4, 3, 5, 5)
        expect = jax.lax.conv_general_dilated(
            x, wk, window_strides=(1, 1), padding=((2, 2), (2, 2)),
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
        ) + b[None, :, None, None]
        np.testing.assert_allclose(out, np.asarray(expect), rtol=2e-3, atol=2e-3)

    def test_maxpool(self):
        x = np.arange(16, dtype=np.float32).reshape(1, 1, 4, 4)
        out = np.asarray(model.maxpool2(x))
        np.testing.assert_array_equal(out[0, 0], [[5, 7], [13, 15]])


class TestCharRnn:
    def setup_method(self):
        self.params = model.init_charrnn(seed=3)
        rng = np.random.default_rng(2)
        self.ids = rng.integers(
            0, model.RNN_VOCAB, (model.RNN_BATCH, model.RNN_STEPS)
        ).astype(np.int32)
        self.labels = onehot(
            self.ids.reshape(-1), model.RNN_VOCAB
        ).reshape(model.RNN_BATCH, model.RNN_STEPS, model.RNN_VOCAB)

    def test_logits_shape(self):
        logits = model.charrnn_logits(self.params, self.ids)
        assert logits.shape == (model.RNN_BATCH, model.RNN_STEPS, model.RNN_VOCAB)

    def test_copy_task_learnable(self):
        # labels == inputs → loss must fall steadily (the per-token loss is
        # averaged over B*T rows, so per-step gradients are small; a modest
        # lr with a handful of steps shows clear descent without divergence)
        params = [p.copy() for p in self.params]
        first = None
        for _ in range(12):
            loss, _, *grads = model.charrnn_step(*params, self.ids, self.labels)
            if first is None:
                first = float(loss)
            params = [p - 8.0 * np.asarray(g) for p, g in zip(params, grads)]
        last = float(loss)
        assert last < first - 0.25, (first, last)


class TestAotCatalogue:
    def test_catalogue_is_consistent(self):
        from compile import aot

        cat = aot.catalogue()
        assert set(cat) == {"mlp_step", "cnn_step", "charrnn_step"}
        for name, (fn, examples, in_names, out_names) in cat.items():
            assert len(examples) == len(in_names), name
            outs = jax.eval_shape(fn, *[aot._spec(e) for e in examples])
            assert len(outs) == len(out_names), name
            # grads pair with params 1:1
            n_params = sum(1 for n in in_names if n.startswith("param:"))
            n_grads = sum(1 for n in out_names if n.startswith("grad:"))
            assert n_params == n_grads, name

    def test_fingerprint_stable(self):
        from compile import aot

        assert aot.source_fingerprint() == aot.source_fingerprint()
