// perf probe: naive vs blocked gemm + convnet timing
use singa::tensor::gemm::{gemm, gemm_ref, Transpose};
use singa::utils::timer::time_iters;
fn main() {
    let n = 256;
    let mut rng = singa::utils::rng::Rng::new(1);
    let a = rng.uniform_vec(n*n, -1.0, 1.0);
    let b = rng.uniform_vec(n*n, -1.0, 1.0);
    let mut c = vec![0.0f32; n*n];
    let st = time_iters(1, 3, || gemm_ref(Transpose::No, Transpose::No, n,n,n, 1.0, &a,&b, 0.0, &mut c));
    println!("naive {n}: {:.2} ms ({:.2} GFLOP/s)", st.mean(), 2.0*(n as f64).powi(3)/(st.mean()/1e3)/1e9);
    let st = time_iters(1, 5, || gemm(Transpose::No, Transpose::No, n,n,n, 1.0, &a,&b, 0.0, &mut c));
    println!("blocked {n}: {:.2} ms ({:.2} GFLOP/s)", st.mean(), 2.0*(n as f64).powi(3)/(st.mean()/1e3)/1e9);
}
