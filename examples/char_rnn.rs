//! Char-RNN over pseudo-C source (paper §4.2.3, Figs 9 & 17): a stacked
//! GRU predicting the next character, trained with BPTT via the BP
//! TrainOneBatch driver. The two GRU stacks are placed on different workers
//! (the paper's Fig 9 coloring) and the run finishes by sampling text from
//! the model.
//!
//! ```sh
//! cargo run --release --example char_rnn
//! ```

use singa::data::{CharCorpus, DataSource};
use singa::model::layer::{Activation, LayerConf, LayerKind};
use singa::model::{NetBuilder, Phase};
use singa::tensor::Blob;
use singa::train::{bp::Bp, TrainOneBatch};
use singa::updater::{Updater, UpdaterConf};
use singa::utils::rng::Rng;

fn main() {
    let steps = 16;
    let batch = 16;
    let hidden = 64;
    let corpus = CharCorpus::pseudo_c(64 * 1024, steps, 7);
    let vocab = corpus.vocab_size();
    println!("corpus: {} bytes, vocab {vocab}", corpus.text.len());

    // 2-stacked GRU (Fig 9), stacks on workers 0 and 1.
    let net = NetBuilder::new()
        .add(LayerConf::new("chars", LayerKind::Input { shape: vec![batch, steps] }, &[]))
        .add(LayerConf::new("labels", LayerKind::Input { shape: vec![batch, steps] }, &[]))
        .add(LayerConf::new("onehot", LayerKind::OneHot { vocab }, &["chars"]))
        .add(
            LayerConf::new("gru1", LayerKind::Gru { hidden, steps, init_std: 0.08 }, &["onehot"]).at(0),
        )
        .add(LayerConf::new("gru2", LayerKind::Gru { hidden, steps, init_std: 0.08 }, &["gru1"]).at(1))
        .add(
            LayerConf::new(
                "proj",
                LayerKind::InnerProduct {
                    out: steps * vocab,
                    act: Activation::Identity,
                    init_std: 0.08,
                },
                &["gru2"],
            )
            .at(1),
        )
        .add(LayerConf::new("loss", LayerKind::SeqSoftmaxLoss { steps }, &["proj", "labels"]).at(1));

    let (pnet, _) = singa::model::partition::partition_net(&net, 2);
    let mut net = pnet.build(&mut Rng::new(21));
    let mut alg = Bp::new();
    let mut upd = Updater::new(UpdaterConf::adagrad(0.08));

    let mut first = None;
    let mut last = (0.0, 0.0);
    for it in 0..400u64 {
        let inputs = corpus.batch(it, batch);
        net.zero_grads();
        let stats = alg.train_one_batch(&mut net, &inputs);
        for p in net.params_mut() {
            upd.update_param(p, it);
        }
        last = (stats.total_loss(), stats.metric());
        if first.is_none() {
            first = Some(last.0);
        }
        if it % 40 == 0 {
            println!("iter {it}: loss {:.4}, next-char accuracy {:.3}", last.0, last.1);
        }
    }
    println!(
        "training: loss {:.3} -> {:.3}, final accuracy {:.3}",
        first.unwrap(),
        last.0,
        last.1
    );
    assert!(last.0 < 0.7 * first.unwrap(), "Char-RNN loss should drop substantially");

    // Sample text: greedy next-char rollout seeded with a corpus snippet.
    let seed_batch = corpus.batch(12345, batch);
    let mut window: Vec<f32> =
        seed_batch["chars"].data()[..steps].to_vec();
    let mut generated = String::new();
    for _ in 0..120 {
        let mut ids = Vec::with_capacity(batch * steps);
        for _ in 0..batch {
            ids.extend_from_slice(&window);
        }
        net.set_input("chars", Blob::from_vec(&[batch, steps], ids.clone()));
        net.set_input("labels", Blob::from_vec(&[batch, steps], ids));
        net.forward(Phase::Test);
        let probs = find_proj(&net);
        // last step's distribution of row 0
        let off = (steps - 1) * vocab;
        let row = &probs.data()[off..off + vocab];
        let next = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        generated.push(corpus.decode(next));
        window.remove(0);
        window.push(next as f32);
    }
    println!("--- sampled text ---\n{generated}\n--------------------");
}

fn find_proj(net: &singa::model::NeuralNet) -> Blob {
    // proj may have been renamed by placement; find a layer whose name
    // starts with "proj".
    for (i, n) in net.nodes().iter().enumerate() {
        if n.layer.name().starts_with("proj") && n.layer.type_name() == "InnerProduct" {
            return net.feature_of(i).clone();
        }
    }
    panic!("proj layer not found");
}
