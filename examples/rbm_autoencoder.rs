//! RBM pre-training + deep auto-encoder fine-tuning for dimensionality
//! reduction (paper §4.2.2, Figs 8 & 16).
//!
//! Stage 1: greedy CD-1 pre-training of a stack of RBMs (784→256→64→8→2).
//! Stage 2: unfold into an auto-encoder initialized from the RBM weights
//! and fine-tune with BP to minimize reconstruction error.
//! Reports reconstruction error and the 2-d code class separation (the
//! quantitative counterpart of the paper's Fig 16b scatter plot).
//!
//! ```sh
//! cargo run --release --example rbm_autoencoder
//! ```

use singa::data::{DataSource, SyntheticDigits};
use singa::model::layer::{Activation, LayerConf, LayerKind};
use singa::model::rbm::RbmLayer;
use singa::model::{NetBuilder, Phase};
use singa::tensor::{ops, Blob};
use singa::train::{bp::Bp, cd::Cd, TrainOneBatch};
use singa::updater::{Updater, UpdaterConf};
use singa::utils::rng::Rng;

const DIMS: [usize; 5] = [784, 256, 64, 8, 2];

fn main() {
    let batch = 32;
    let data = SyntheticDigits::mnist_like(5);

    // ---- Stage 1: stacked RBMs, greedy CD-1 (paper Fig 8 steps 1-2) ----
    let mut b = NetBuilder::new()
        .add(LayerConf::new("data", LayerKind::Input { shape: vec![batch, DIMS[0]] }, &[]));
    for i in 1..DIMS.len() {
        let src = if i == 1 { "data".to_string() } else { format!("rbm{}", i - 1) };
        b = b.add(LayerConf::new(
            &format!("rbm{i}"),
            LayerKind::Rbm { hidden: DIMS[i], init_std: 0.05 },
            &[&src],
        ));
    }
    let mut net = b.build(&mut Rng::new(8));
    for stage in 1..DIMS.len() {
        let mut alg = Cd::stage(1, &format!("rbm{stage}"));
        let mut last = 0.0;
        for it in 0..250u64 {
            let inputs = data.batch(it, batch);
            net.zero_grads();
            let stats = alg.train_one_batch(&mut net, &inputs);
            for p in net.params_mut() {
                p.sgd_step(0.05);
            }
            last = stats.total_loss();
        }
        println!("pre-train rbm{stage}: final reconstruction error {last:.4}");
    }

    // Export the learned weights (checkpoint, as in the paper's Fig 8).
    let mut weights: Vec<(Blob, Blob, Blob)> = Vec::new(); // (W, hbias, vbias)
    for i in 1..DIMS.len() {
        let idx = net.index_of(&format!("rbm{i}")).unwrap();
        let rbm = net.nodes_mut()[idx].layer.as_any().downcast_mut::<RbmLayer>().unwrap();
        weights.push((rbm.weight.data.clone(), rbm.hbias.data.clone(), rbm.vbias.data.clone()));
    }

    // ---- Stage 2: unfold into an auto-encoder and fine-tune with BP ----
    // Encoder layers use W, decoder layers use W^T (tied init, untied train).
    let mut b = NetBuilder::new()
        .add(LayerConf::new("data", LayerKind::Input { shape: vec![batch, DIMS[0]] }, &[]));
    let mut prev = "data".to_string();
    for i in 1..DIMS.len() {
        let name = format!("enc{i}");
        b = b.add(LayerConf::new(
            &name,
            LayerKind::InnerProduct { out: DIMS[i], act: Activation::Sigmoid, init_std: 0.01 },
            &[&prev],
        ));
        prev = name;
    }
    for i in (1..DIMS.len()).rev() {
        let name = format!("dec{i}");
        b = b.add(LayerConf::new(
            &name,
            LayerKind::InnerProduct { out: DIMS[i - 1], act: Activation::Sigmoid, init_std: 0.01 },
            &[&prev],
        ));
        prev = name;
    }
    b = b.add(LayerConf::new("recon", LayerKind::EuclideanLoss { weight: 1.0 }, &[&prev, "data"]));
    let mut ae = b.build(&mut Rng::new(9));

    // Port the checkpointed RBM weights into the encoder/decoder.
    for (i, (w, hb, vb)) in weights.iter().enumerate() {
        let layer = i + 1;
        for p in ae.params_mut() {
            if p.name == format!("enc{layer}/weight") {
                p.data = w.clone();
            } else if p.name == format!("enc{layer}/bias") {
                p.data = hb.clone();
            } else if p.name == format!("dec{layer}/weight") {
                p.data = transpose(w);
            } else if p.name == format!("dec{layer}/bias") {
                p.data = vb.clone();
            }
        }
    }

    let mut alg = Bp::new();
    let mut upd = Updater::new(UpdaterConf::sgd(0.02));
    let mut first = None;
    let mut last = 0.0;
    for it in 0..300u64 {
        let inputs = data.batch(10_000 + it, batch);
        ae.zero_grads();
        let stats = alg.train_one_batch(&mut ae, &inputs);
        for p in ae.params_mut() {
            upd.update_param(p, it);
        }
        last = stats.total_loss();
        if first.is_none() {
            first = Some(last);
        }
        if it % 50 == 0 {
            println!("fine-tune iter {it}: reconstruction loss {last:.4}");
        }
    }
    println!(
        "fine-tuning: {:.4} -> {last:.4} (lower is better)",
        first.unwrap()
    );

    // 2-d codes: class separation ratio (paper Fig 16b shows clusters).
    let test = data.batch(77_000, 128);
    ae.set_input("data", test["data"].clone());
    ae.forward(Phase::Test);
    let codes = ae.feature(&format!("enc{}", DIMS.len() - 1)).clone();
    let labels: Vec<usize> = test["label"].data().iter().map(|&v| v as usize).collect();
    let sep = separation(&codes, &labels);
    println!("2-d code class-separation ratio: {sep:.3} (>1 = clustered by class)");
    assert!(last < first.unwrap(), "fine-tuning must reduce reconstruction error");
}

fn transpose(w: &Blob) -> Blob {
    let (r, c) = (w.rows(), w.cols());
    let mut out = Blob::zeros(&[c, r]);
    for i in 0..r {
        for j in 0..c {
            out.data_mut()[j * r + i] = w.data()[i * c + j];
        }
    }
    out
}

fn separation(codes: &Blob, labels: &[usize]) -> f32 {
    let d = codes.cols();
    let dist = |a: usize, b: usize| -> f32 {
        ops::zip(
            &Blob::from_vec(&[d], codes.data()[a * d..(a + 1) * d].to_vec()),
            &Blob::from_vec(&[d], codes.data()[b * d..(b + 1) * d].to_vec()),
            |x, y| (x - y) * (x - y),
        )
        .sum()
        .sqrt()
    };
    let n = labels.len();
    let (mut within, mut wn, mut between, mut bn) = (0.0f32, 0u32, 0.0f32, 0u32);
    for i in 0..n {
        for j in (i + 1)..n {
            if labels[i] == labels[j] {
                within += dist(i, j);
                wn += 1;
            } else {
                between += dist(i, j);
                bn += 1;
            }
        }
    }
    (between / bn.max(1) as f32) / (within / wn.max(1) as f32).max(1e-9)
}
