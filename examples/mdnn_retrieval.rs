//! MDNN for multi-modal retrieval (paper §4.2.1, Figs 7 & 15).
//!
//! Two parallel paths — a small CNN for images, an MLP for text — trained
//! with (1) per-modality softmax label losses and (2) a euclidean loss
//! pulling the two embeddings of the same object together. The paths are
//! placed on different workers via location ids (the paper's example of
//! explicit placement). After training we run image→text retrieval and
//! report precision@k.
//!
//! ```sh
//! cargo run --release --example mdnn_retrieval
//! ```

use singa::data::{DataSource, MultiModalPairs};
use singa::model::layer::{Activation, LayerConf, LayerKind};
use singa::model::{NetBuilder, Phase};
use singa::tensor::Blob;
use singa::train::{bp::Bp, TrainOneBatch};
use singa::updater::{Updater, UpdaterConf};
use singa::utils::rng::Rng;

fn main() {
    let batch = 16;
    let embed = 32;
    let data = MultiModalPairs::nuswide_like(13);
    let classes = data.classes;

    // Image path at worker 0, text path at worker 1 (paper §5.3).
    let net = NetBuilder::new()
        .add(LayerConf::new("image", LayerKind::Input { shape: vec![batch, 3, 16, 16] }, &[]))
        .add(LayerConf::new("text", LayerKind::Input { shape: vec![batch, 64] }, &[]))
        .add(LayerConf::new("label", LayerKind::Input { shape: vec![batch] }, &[]))
        // image path (DCNN-ish)
        .add(
            LayerConf::new(
                "conv1",
                LayerKind::Convolution { out_channels: 8, kernel: 3, stride: 1, pad: 1, init_std: 0.1 },
                &["image"],
            )
            .at(0),
        )
        .add(LayerConf::new("pool1", LayerKind::MaxPool { kernel: 2, stride: 2 }, &["conv1"]).at(0))
        .add(LayerConf::new("relu1", LayerKind::Activation { act: Activation::Relu }, &["pool1"]).at(0))
        .add(
            LayerConf::new(
                "img_embed",
                LayerKind::InnerProduct { out: embed, act: Activation::Tanh, init_std: 0.05 },
                &["relu1"],
            )
            .at(0),
        )
        .add(
            LayerConf::new(
                "img_logits",
                LayerKind::InnerProduct { out: classes, act: Activation::Identity, init_std: 0.05 },
                &["img_embed"],
            )
            .at(0),
        )
        .add(LayerConf::new("img_loss", LayerKind::SoftmaxLoss, &["img_logits", "label"]).at(0))
        // text path (MLP)
        .add(
            LayerConf::new(
                "txt_h",
                LayerKind::InnerProduct { out: 64, act: Activation::Sigmoid, init_std: 0.1 },
                &["text"],
            )
            .at(1),
        )
        .add(
            LayerConf::new(
                "txt_embed",
                LayerKind::InnerProduct { out: embed, act: Activation::Tanh, init_std: 0.05 },
                &["txt_h"],
            )
            .at(1),
        )
        .add(
            LayerConf::new(
                "txt_logits",
                LayerKind::InnerProduct { out: classes, act: Activation::Identity, init_std: 0.05 },
                &["txt_embed"],
            )
            .at(1),
        )
        .add(LayerConf::new("txt_loss", LayerKind::SoftmaxLoss, &["txt_logits", "label"]).at(1))
        // cross-modal objective
        .add(LayerConf::new("dist", LayerKind::EuclideanLoss { weight: 0.05 }, &["img_embed", "txt_embed"]));

    // Partitioning pass inserts bridges on the cross-path edges.
    let (pnet, _plan) = singa::model::partition::partition_net(&net, 2);
    let mut net = pnet.build(&mut Rng::new(3));
    let mut alg = Bp::new();
    let mut upd = Updater::new(UpdaterConf::adagrad(0.08));

    for it in 0..700u64 {
        let inputs = data.batch(it, batch);
        net.zero_grads();
        let stats = alg.train_one_batch(&mut net, &inputs);
        for p in net.params_mut() {
            upd.update_param(p, it);
        }
        if it % 100 == 0 {
            let l: Vec<String> =
                stats.losses.iter().map(|(n, l, m)| format!("{n}={l:.3}/{m:.2}")).collect();
            println!("iter {it}: {}", l.join("  "));
        }
    }

    // Retrieval: embed a held-out batch, query images against texts.
    let test = data.batch(99_991, 64);
    net.set_input("image", test["image"].clone());
    net.set_input("text", test["text"].clone());
    net.set_input("label", test["label"].clone());
    net.forward(Phase::Test);
    let img = net.feature("img_embed").clone();
    let txt = net.feature("txt_embed").clone();
    let labels: Vec<usize> = test["label"].data().iter().map(|&v| v as usize).collect();

    let p_at_5 = precision_at_k(&img, &txt, &labels, 5);
    println!("image→text precision@5 = {p_at_5:.3} (chance = {:.3})", 1.0 / classes as f32);
    assert!(
        p_at_5 > 2.0 / classes as f32,
        "retrieval should beat chance: {p_at_5}"
    );
}

/// Fraction of top-k retrieved texts sharing the query image's class.
fn precision_at_k(queries: &Blob, corpus: &Blob, labels: &[usize], k: usize) -> f32 {
    let n = queries.rows();
    let d = queries.cols();
    let mut hit = 0.0;
    for q in 0..n {
        let qv = &queries.data()[q * d..(q + 1) * d];
        let mut dists: Vec<(f32, usize)> = (0..corpus.rows())
            .map(|c| {
                let cv = &corpus.data()[c * d..(c + 1) * d];
                let dist: f32 = qv.iter().zip(cv).map(|(a, b)| (a - b) * (a - b)).sum();
                (dist, c)
            })
            .collect();
        dists.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let hits = dists.iter().take(k).filter(|(_, c)| labels[*c] == labels[q]).count();
        hit += hits as f32 / k as f32;
    }
    hit / n as f32
}
