//! End-to-end driver: the full three-layer stack on a real workload.
//!
//! Part 1 trains the CIFAR convnet (~0.9M params) through the native
//! coordinator with a synchronous worker group, logging the loss curve.
//! Part 2 trains the AOT-compiled JAX+Pallas MLP through PJRT — the
//! production path where rust executes XLA artifacts and python is absent.
//! Both runs are recorded in EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_train
//! ```

use singa::cluster::ClusterTopology;
use singa::coordinator::{run_job, JobConf};
use singa::data::{SyntheticDigits, SyntheticImages};
use singa::runtime::xla_job::{onehot_batcher, run_xla_job, XlaJobConf};
use singa::runtime::XlaRuntime;
use singa::updater::UpdaterConf;
use std::sync::Arc;

fn main() {
    // ---- Part 1: native coordinator, CIFAR convnet, 300 steps ----
    let batch = 32;
    let net = singa::bench::cifar_convnet(batch);
    {
        let probe = singa::bench::cifar_convnet(batch)
            .build(&mut singa::utils::rng::Rng::new(1));
        println!(
            "cifar convnet: {} layers, {} params",
            probe.len(),
            probe.param_count()
        );
    }
    let mut conf = JobConf::new("e2e-cifar", net);
    conf.batch_size = batch;
    conf.iters = 300;
    conf.updater = UpdaterConf::sgd_momentum(0.02, 0.9);
    conf.topology = ClusterTopology::sandblaster(1, 1);
    conf.log_every = 10;
    let data = Arc::new(SyntheticImages::cifar_like(17));
    let report = run_job(&conf, data);
    println!("--- native loss curve (every 10 iters) ---");
    print!("{}", report.log.to_tsv());
    let recs = report.log.snapshot();
    let (first, last) = (recs.first().unwrap(), recs.last().unwrap());
    println!(
        "native: loss {:.3} -> {:.3}, accuracy {:.3}, wall {:.1} s",
        first.loss,
        last.loss,
        last.metric,
        report.wall_ms / 1e3
    );
    assert!(last.loss < 0.5 * first.loss, "convnet loss must halve");
    assert!(last.metric > 0.8, "convnet accuracy must exceed 0.8");

    // ---- Part 2: XLA/PJRT path (L3 + RT + L2 + L1 composed) ----
    if XlaRuntime::default_dir().join("manifest.json").exists() {
        let mut xconf = XlaJobConf::new("mlp_step");
        xconf.iters = 100;
        xconf.updater = UpdaterConf::sgd(0.3);
        xconf.log_every = 10;
        let src = Arc::new(SyntheticDigits::new(784, 10, 5));
        let batcher = onehot_batcher(src, 32, 10, "data", "label_onehot");
        let xrep = run_xla_job(&xconf, batcher).expect("xla job");
        println!("--- XLA (PJRT) loss curve ---");
        print!("{}", xrep.log.to_tsv());
        let xrecs = xrep.log.snapshot();
        let (xf, xl) = (xrecs.first().unwrap(), xrecs.last().unwrap());
        println!(
            "xla: loss {:.3} -> {:.3}, wall {:.1} s, {} param bytes moved",
            xf.loss,
            xl.loss,
            xrep.wall_ms / 1e3,
            xrep.ledger.param_bytes()
        );
        assert!(xl.loss < 0.3 * xf.loss, "XLA MLP loss must drop to <30%");
    } else {
        println!("(artifacts missing — run `make artifacts` to exercise the XLA path)");
    }
    println!("e2e OK");
}
