//! Quickstart: the SINGA programming model in ~40 lines.
//!
//! Define a NeuralNet from layer configs, pick the BP TrainOneBatch
//! algorithm and an updater, choose a cluster topology (single worker
//! group = synchronous), and run.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use singa::cluster::ClusterTopology;
use singa::coordinator::{run_job, Algorithm, JobConf};
use singa::data::SyntheticDigits;
use singa::model::layer::{Activation, LayerConf, LayerKind};
use singa::model::NetBuilder;
use singa::updater::UpdaterConf;
use std::sync::Arc;

fn main() {
    let batch = 32;
    // 1. NeuralNet: layers + connections (paper §4.1.1).
    let net = NetBuilder::new()
        .add(LayerConf::new("data", LayerKind::Input { shape: vec![batch, 784] }, &[]))
        .add(LayerConf::new("label", LayerKind::Input { shape: vec![batch] }, &[]))
        .add(LayerConf::new(
            "hidden",
            LayerKind::InnerProduct { out: 128, act: Activation::Relu, init_std: 0.05 },
            &["data"],
        ))
        .add(LayerConf::new(
            "logits",
            LayerKind::InnerProduct { out: 10, act: Activation::Identity, init_std: 0.05 },
            &["hidden"],
        ))
        .add(LayerConf::new("loss", LayerKind::SoftmaxLoss, &["logits", "label"]));

    // 2-4. TrainOneBatch + Updater + ClusterTopology (paper §3).
    let mut conf = JobConf::new("quickstart", net);
    conf.algorithm = Algorithm::Bp;
    conf.updater = UpdaterConf::sgd_momentum(0.1, 0.9);
    conf.topology = ClusterTopology::sandblaster(1, 1);
    conf.batch_size = batch;
    conf.iters = 150;
    conf.log_every = 10;

    let data = Arc::new(SyntheticDigits::mnist_like(7));
    let report = run_job(&conf, data);
    print!("{}", report.log.to_tsv());
    let recs = report.log.snapshot();
    let last = recs.last().unwrap();
    println!(
        "final: loss {:.4}, accuracy {:.3} ({} param bytes moved, wall {:.0} ms)",
        last.loss,
        last.metric,
        report.ledger.param_bytes(),
        report.wall_ms
    );
    assert!(last.metric > 0.9, "quickstart should reach >0.9 train accuracy");
}
